//! Seeded chaos suite for the serving stack: each test arms one named
//! deterministic fault schedule (`util/failpoint`), drives load through
//! the TCP wire path, then disarms and asserts the self-healing
//! invariants the stack promises:
//!
//!   * the server and coordinator join cleanly (no panic, no wedge);
//!   * zero leaks — `live_seqs == 0`, `blocks_in_use == 0`, and the
//!     global in-flight gauge back to 0 (all read off the `metrics`
//!     control frame);
//!   * every submitted request reaches a terminal state **exactly once**
//!     (a rejection, a terminal event, or a transport error — never
//!     silence, never a duplicate);
//!   * same-seed reruns inject the identical fault sequence (schedules
//!     are functions of hit counters, never the wall clock).
//!
//! The failpoint registry is process-global, so every test serializes on
//! [`GATE`] and leaves the process disarmed. Needs artifacts/ and skips
//! gracefully without it — same convention as server_wire_tests.rs. The
//! `chaos_smoke_*` subset is fast enough for scripts/check.sh.

use recalkv::artifacts::Manifest;
use recalkv::coordinator::{Coordinator, Engine, EngineConfig};
use recalkv::server::{
    generate_with_retry, run_load, Client, ClientFrame, GenOutcome, Server, ServerConfig,
    ServerFrame, WireErrorKind, WireEvent, WireRequest, MAX_FRAME_LEN,
};
use recalkv::util::backoff::ADMISSION_RETRY;
use recalkv::util::failpoint;
use recalkv::util::json::Json;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

const PROMPT: &str = "the dog barks . the cat sleeps . ";

/// The failpoint registry is process-global and cargo runs tests on
/// parallel threads: every chaos test serializes here and disarms on the
/// way out (even on panic, via [`Disarm`]).
static GATE: Mutex<()> = Mutex::new(());

struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        failpoint::reset();
    }
}

fn serialized(f: impl FnOnce()) {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    failpoint::reset();
    let _disarm = Disarm;
    f();
}

fn manifest_dir() -> Option<PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] artifacts/ not built");
        return None;
    }
    Some(dir)
}

fn spawn_server(
    dir: PathBuf,
    ecfg: EngineConfig,
    scfg: ServerConfig,
) -> (String, Coordinator, std::thread::JoinHandle<anyhow::Result<()>>) {
    let coord = Coordinator::spawn(move || {
        let man = Manifest::load(&dir)?;
        let rt = recalkv::runtime::Runtime::cpu()?;
        let model = man.model("tiny-mha")?;
        Engine::new(&rt, model, model.variant("recal@50")?, ecfg)
    });
    let server = Server::bind("127.0.0.1:0", coord.handle(), scfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let worker = std::thread::spawn(move || server.run());
    (addr, coord, worker)
}

/// Clean join: must only be called with the failpoints already disarmed
/// (the shutdown handshake rides the same client/conn seams).
fn stop_server(addr: &str, coord: Coordinator, worker: std::thread::JoinHandle<anyhow::Result<()>>) {
    assert!(!failpoint::armed(), "disarm before the shutdown handshake");
    let mut c = Client::connect(addr).expect("connect for shutdown");
    c.shutdown_server().expect("shutdown handshake");
    worker.join().expect("server thread panicked").expect("server run failed");
    coord.shutdown().expect("coordinator shutdown");
}

fn num(j: &Json, path: &[&str]) -> f64 {
    let mut cur = j;
    for k in path {
        cur = cur.req(k);
    }
    cur.as_f64().unwrap_or_else(|| panic!("{path:?} is not a number in {j}", j = cur))
}

/// Poll the `metrics` control frame until the engine is idle again
/// (`live_seqs == 0` and the global in-flight gauge at 0). Call only
/// after disarming — the observer connections ride the chaos seams too.
fn await_quiescence(addr: &str, what: &str) -> Json {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut c = Client::connect(addr).expect("metrics connection");
        let j = c.metrics().expect("metrics frame");
        if num(&j, &["cache", "live_seqs"]) == 0.0 && num(&j, &["inflight"]) == 0.0 {
            return j;
        }
        assert!(Instant::now() < deadline, "`{what}` did not quiesce: {j}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn assert_leak_free(j: &Json, what: &str) {
    assert_eq!(num(j, &["cache", "live_seqs"]), 0.0, "`{what}` leaked sequences");
    assert_eq!(num(j, &["cache", "blocks_in_use"]), 0.0, "`{what}` leaked cache blocks");
    assert_eq!(num(j, &["inflight"]), 0.0, "`{what}` leaked in-flight slots");
}

/// Boot a server, arm `spec`, run `drive`, then disarm and assert the
/// no-leak invariant before a clean shutdown. Returns how many faults the
/// schedule injected while `drive` ran (`None` = skipped, no artifacts).
fn run_schedule(
    spec: &str,
    ecfg: EngineConfig,
    scfg: ServerConfig,
    drive: impl FnOnce(&str),
) -> Option<u64> {
    let dir = manifest_dir()?;
    let (addr, coord, worker) = spawn_server(dir, ecfg, scfg);
    failpoint::configure(spec).expect("chaos spec parses");
    drive(&addr);
    let injected = failpoint::injected_total();
    failpoint::reset();
    let j = await_quiescence(&addr, spec);
    assert_leak_free(&j, spec);
    stop_server(&addr, coord, worker);
    Some(injected)
}

fn last_event(events: &[(WireEvent, Instant)]) -> &WireEvent {
    let (ev, _) = events.last().expect("session delivered no events");
    ev
}

fn assert_exactly_one_terminal(events: &[(WireEvent, Instant)], what: &str) {
    let terminals = events.iter().filter(|(ev, _)| ev.is_terminal()).count();
    assert_eq!(terminals, 1, "`{what}`: want exactly one terminal event, got {terminals}");
}

// ---------------------------------------------------------------------------
// engine-side faults: the worker survives, only the owning request fails

#[test]
fn chaos_pool_alloc_nth_fails_only_the_owning_request() {
    serialized(|| {
        let injected = run_schedule(
            "pool.alloc=err:nth(3)",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                let mut c = Client::connect(addr).expect("connect");
                match c.generate(&WireRequest::new(1, PROMPT, 64)).expect("transport held") {
                    GenOutcome::Done { events } => {
                        assert!(
                            matches!(last_event(&events), WireEvent::Failed(_)),
                            "a forced pool exhaustion must fail the request, got {:?}",
                            last_event(&events)
                        );
                        assert_exactly_one_terminal(&events, "pool.alloc nth(3)");
                    }
                    GenOutcome::Rejected(e) => panic!("unexpected rejection: {e:?}"),
                }
            },
        );
        if let Some(injected) = injected {
            assert_eq!(injected, 1, "nth(3) fires exactly once");
        }
    });
}

#[test]
fn chaos_pool_alloc_every_under_concurrent_load() {
    serialized(|| {
        let _ = run_schedule(
            "pool.alloc=err:every(5)",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                let report = run_load(addr, 2, 3, &[PROMPT.to_string()], 16)
                    .expect("run_load survives engine-side faults");
                assert_eq!(report.requests, 6, "every request must terminate: {}", report.summary());
                assert_eq!(
                    report.completed + report.failed + report.rejected,
                    6,
                    "requests vanished: {}",
                    report.summary()
                );
                assert!(
                    report.failed >= 1,
                    "every(5) across 6 allocating requests should fail at least one: {}",
                    report.summary()
                );
            },
        );
    });
}

#[test]
fn chaos_cache_append_once_fails_request_not_worker() {
    serialized(|| {
        let injected = run_schedule(
            "cache.append=err:once",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                let mut c = Client::connect(addr).expect("connect");
                match c.generate(&WireRequest::new(1, PROMPT, 16)).expect("transport held") {
                    GenOutcome::Done { events } => {
                        assert!(
                            matches!(last_event(&events), WireEvent::Failed(_)),
                            "append rejection must fail the request, got {:?}",
                            last_event(&events)
                        );
                        assert_exactly_one_terminal(&events, "cache.append once");
                    }
                    GenOutcome::Rejected(e) => panic!("unexpected rejection: {e:?}"),
                }
                // the worker survived: a fault-free request completes
                match c.generate(&WireRequest::new(2, PROMPT, 4)).expect("transport held") {
                    GenOutcome::Done { events } => assert!(
                        matches!(last_event(&events), WireEvent::Finished(_)),
                        "worker should serve cleanly after the fault, got {:?}",
                        last_event(&events)
                    ),
                    GenOutcome::Rejected(e) => panic!("post-fault request rejected: {e:?}"),
                }
            },
        );
        if let Some(injected) = injected {
            assert_eq!(injected, 1, "once fires exactly once");
        }
    });
}

#[test]
fn chaos_cache_stage_nth_fails_request_not_worker() {
    serialized(|| {
        let _ = run_schedule(
            "cache.stage=err:nth(2)",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                let mut c = Client::connect(addr).expect("connect");
                match c.generate(&WireRequest::new(1, PROMPT, 16)).expect("transport held") {
                    GenOutcome::Done { events } => {
                        assert!(
                            matches!(last_event(&events), WireEvent::Failed(_)),
                            "stage rejection must fail the request, got {:?}",
                            last_event(&events)
                        );
                        assert_exactly_one_terminal(&events, "cache.stage nth(2)");
                    }
                    GenOutcome::Rejected(e) => panic!("unexpected rejection: {e:?}"),
                }
            },
        );
    });
}

// ---------------------------------------------------------------------------
// router faults: typed rejections, retry healing, exactly-once terminals

#[test]
fn chaos_smoke_submit_retry_storm() {
    serialized(|| {
        let injected = run_schedule(
            "router.submit=err:first(5)",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                let mut slot = Some(Client::connect(addr).expect("connect"));
                let mut total_retries = 0u32;
                for r in 0..3u64 {
                    let (outcome, retries) = generate_with_retry(
                        addr,
                        &mut slot,
                        &WireRequest::new(r + 1, PROMPT, 4),
                        &ADMISSION_RETRY,
                    )
                    .expect("retry loop");
                    total_retries += retries;
                    match outcome {
                        GenOutcome::Done { events } => assert!(
                            matches!(last_event(&events), WireEvent::Finished(_)),
                            "request {r} did not finish: {:?}",
                            last_event(&events)
                        ),
                        GenOutcome::Rejected(e) => {
                            panic!("request {r} rejected through the retry budget: {e:?}")
                        }
                    }
                }
                assert_eq!(total_retries, 5, "first(5) forces exactly five retries");
                // the metrics frame carries the robustness counters while armed
                let mut obs = Client::connect(addr).expect("observer");
                let j = obs.metrics().expect("metrics");
                assert_eq!(num(&j, &["metrics", "faults_injected"]), 5.0);
                assert!(num(&j, &["metrics", "requests_retried"]) >= 5.0);
            },
        );
        if let Some(injected) = injected {
            assert_eq!(injected, 5);
        }
    });
}

#[test]
fn chaos_run_load_absorbs_injected_queue_full_storm() {
    serialized(|| {
        let _ = run_schedule(
            "router.submit=err:first(6)",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                let report = run_load(addr, 3, 4, &[PROMPT.to_string()], 8)
                    .expect("run_load survives the storm");
                assert_eq!(report.completed, 12, "storm left requests behind: {}", report.summary());
                assert_eq!(report.failed, 0, "storm failed requests: {}", report.summary());
                assert_eq!(report.rejected, 0, "retryable rejections leaked out: {}", report.summary());
                assert!(
                    report.retries >= 6,
                    "six injected queue_fulls must surface as retries: {}",
                    report.summary()
                );
                assert!(report.requests_retried >= 1, "{}", report.summary());
            },
        );
    });
}

#[test]
fn chaos_router_ack_drop_surfaces_typed_rejection() {
    serialized(|| {
        let injected = run_schedule(
            "router.ack=err:once",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                let mut c = Client::connect(addr).expect("connect");
                match c.generate(&WireRequest::new(1, PROMPT, 4)).expect("transport held") {
                    GenOutcome::Rejected(e) => {
                        assert!(
                            matches!(e.kind, WireErrorKind::ShuttingDown),
                            "a dropped ack must surface as a typed shutdown rejection: {e:?}"
                        );
                        assert!(!e.kind.retryable());
                    }
                    GenOutcome::Done { .. } => panic!("dropped ack reported success"),
                }
                // same connection stays usable; the orphaned admission
                // drains on its own (asserted leak-free by the harness)
                match c.generate(&WireRequest::new(2, PROMPT, 4)).expect("transport held") {
                    GenOutcome::Done { events } => assert!(
                        matches!(last_event(&events), WireEvent::Finished(_)),
                        "post-fault request did not finish: {:?}",
                        last_event(&events)
                    ),
                    GenOutcome::Rejected(e) => panic!("post-fault request rejected: {e:?}"),
                }
            },
        );
        if let Some(injected) = injected {
            assert_eq!(injected, 1);
        }
    });
}

#[test]
fn chaos_router_event_drops_keep_terminals_exactly_once() {
    serialized(|| {
        let injected = run_schedule(
            "router.event=err:every(3)",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                const REQS: u64 = 4;
                let mut c = Client::connect(addr).expect("connect");
                for id in 1..=REQS {
                    c.send(&ClientFrame::Gen(WireRequest::new(id, PROMPT, 8)))
                        .expect("pipelined send");
                }
                let mut terminals: HashMap<u64, usize> = HashMap::new();
                while terminals.values().copied().sum::<usize>() < REQS as usize {
                    match c.recv().expect("stream") {
                        ServerFrame::Event(ev) if ev.is_terminal() => {
                            *terminals.entry(ev.id()).or_insert(0) += 1;
                        }
                        ServerFrame::Event(_) => {}
                        other => panic!("unexpected frame {other:?}"),
                    }
                }
                // sentinel probe: anything terminal between here and the
                // metrics reply would be a duplicate delivery
                c.send(&ClientFrame::Metrics).expect("probe send");
                loop {
                    match c.recv().expect("probe") {
                        ServerFrame::Metrics(_) => break,
                        ServerFrame::Event(ev) => {
                            assert!(!ev.is_terminal(), "duplicate terminal after drain: {ev:?}")
                        }
                        other => panic!("unexpected frame {other:?}"),
                    }
                }
                for id in 1..=REQS {
                    assert_eq!(
                        terminals.get(&id).copied().unwrap_or(0),
                        1,
                        "request {id} must terminate exactly once"
                    );
                }
            },
        );
        if let Some(injected) = injected {
            assert!(injected >= 1, "every(3) across four sessions should drop something");
        }
    });
}

// ---------------------------------------------------------------------------
// transport faults: reconnect healing and load shedding

#[test]
fn chaos_conn_write_error_heals_by_reconnect() {
    serialized(|| {
        let injected = run_schedule(
            "conn.write=err:nth(2)",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                // hit 1 is this connection's hello_ok; hit 2 kills the first
                // event write of the generation — before any token streamed,
                // so the retry layer may safely resubmit on a fresh socket.
                let mut slot = Some(Client::connect(addr).expect("connect"));
                let (outcome, retries) = generate_with_retry(
                    addr,
                    &mut slot,
                    &WireRequest::new(1, PROMPT, 4),
                    &ADMISSION_RETRY,
                )
                .expect("retry loop");
                assert_eq!(retries, 1, "one forged write failure, one reconnect retry");
                match outcome {
                    GenOutcome::Done { events } => assert!(
                        matches!(last_event(&events), WireEvent::Finished(_)),
                        "did not finish after reconnect: {:?}",
                        last_event(&events)
                    ),
                    GenOutcome::Rejected(e) => panic!("unexpected rejection: {e:?}"),
                }
            },
        );
        if let Some(injected) = injected {
            assert_eq!(injected, 1);
        }
    });
}

#[test]
fn chaos_slow_consumer_is_shed_and_reclaimed() {
    serialized(|| {
        let Some(dir) = manifest_dir() else { return };
        let scfg = ServerConfig { event_queue_cap: 2, ..Default::default() };
        let (addr, coord, worker) = spawn_server(dir, EngineConfig::default(), scfg);
        let mut obs = Client::connect(&addr).expect("observer");
        let before = obs.metrics().expect("baseline metrics");
        let (shed_reqs_0, shed_conns_0) = (
            num(&before, &["server", "shed_requests"]),
            num(&before, &["server", "shed_conns"]),
        );

        // Every server-side write now stalls 50ms: the 2-slot event queue
        // overflows within a few decoded tokens and the connection is shed.
        failpoint::configure("conn.write=delay(50ms)").expect("chaos spec parses");
        let mut c = Client::connect(&addr).expect("slow consumer");
        match c.generate(&WireRequest::new(1, PROMPT, 400)) {
            // shed mid-stream: the socket is torn down under the client
            Err(_) => {}
            // ... or the cancel terminal squeezed out before the teardown
            Ok(GenOutcome::Done { events }) => assert!(
                matches!(last_event(&events), WireEvent::Cancelled(_)),
                "a shed connection's request must cancel, got {:?}",
                last_event(&events)
            ),
            Ok(GenOutcome::Rejected(e)) => panic!("unexpected rejection: {e:?}"),
        }
        failpoint::reset();

        let j = await_quiescence(&addr, "conn.write delay(50ms) shed");
        assert_leak_free(&j, "conn.write delay(50ms) shed");
        assert!(
            num(&j, &["server", "shed_requests"]) >= shed_reqs_0 + 1.0,
            "the stalled consumer's request was not counted shed: {j}"
        );
        assert!(
            num(&j, &["server", "shed_conns"]) >= shed_conns_0 + 1.0,
            "the stalled connection was not counted shed: {j}"
        );
        // the engine-facing metrics overlay carries the same counter
        assert_eq!(
            num(&j, &["metrics", "requests_shed"]),
            num(&j, &["server", "shed_requests"]),
            "requests_shed overlay out of sync: {j}"
        );
        stop_server(&addr, coord, worker);
    });
}

#[test]
fn chaos_client_send_errors_heal_by_reconnect() {
    serialized(|| {
        let injected = run_schedule(
            "client.send=err(2)",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                // first(2): the first two client writes — both handshake
                // sends of the first two connect attempts — are forged
                // failures; the third attempt connects and completes.
                let mut slot: Option<Client> = None;
                let (outcome, retries) = generate_with_retry(
                    addr,
                    &mut slot,
                    &WireRequest::new(1, PROMPT, 4),
                    &ADMISSION_RETRY,
                )
                .expect("retry loop");
                assert_eq!(retries, 2, "two forged send failures, two retries");
                match outcome {
                    GenOutcome::Done { events } => assert!(
                        matches!(last_event(&events), WireEvent::Finished(_)),
                        "did not finish after reconnects: {:?}",
                        last_event(&events)
                    ),
                    GenOutcome::Rejected(e) => panic!("unexpected rejection: {e:?}"),
                }
            },
        );
        if let Some(injected) = injected {
            assert_eq!(injected, 2);
        }
    });
}

#[test]
fn chaos_client_recv_error_heals_by_reconnect() {
    serialized(|| {
        let injected = run_schedule(
            "client.recv=err:once",
            EngineConfig::default(),
            ServerConfig::default(),
            |addr| {
                let mut slot: Option<Client> = None;
                let (outcome, retries) = generate_with_retry(
                    addr,
                    &mut slot,
                    &WireRequest::new(1, PROMPT, 4),
                    &ADMISSION_RETRY,
                )
                .expect("retry loop");
                assert_eq!(retries, 1, "one forged read failure, one retry");
                match outcome {
                    GenOutcome::Done { events } => assert!(
                        matches!(last_event(&events), WireEvent::Finished(_)),
                        "did not finish after reconnect: {:?}",
                        last_event(&events)
                    ),
                    GenOutcome::Rejected(e) => panic!("unexpected rejection: {e:?}"),
                }
            },
        );
        if let Some(injected) = injected {
            assert_eq!(injected, 1);
        }
    });
}

// ---------------------------------------------------------------------------
// retry-policy edges and schedule determinism

#[test]
fn chaos_smoke_too_large_never_retried() {
    serialized(|| {
        let injected = run_schedule(
            "router.submit=err:first(2)",
            EngineConfig { max_cache_tokens: 16, ..Default::default() },
            ServerConfig::default(),
            |addr| {
                let mut slot = Some(Client::connect(addr).expect("connect"));
                let (outcome, retries) = generate_with_retry(
                    addr,
                    &mut slot,
                    &WireRequest::new(1, "way past the cache budget for sure", 64),
                    &ADMISSION_RETRY,
                )
                .expect("retry loop");
                match outcome {
                    GenOutcome::Rejected(e) => assert!(
                        matches!(e.kind, WireErrorKind::TooLarge { .. }),
                        "want too_large through the retry layer: {e:?}"
                    ),
                    GenOutcome::Done { .. } => panic!("oversized request was admitted"),
                }
                assert_eq!(
                    retries, 2,
                    "the injected queue_fulls are retried; the too_large behind them is not"
                );
            },
        );
        if let Some(injected) = injected {
            assert_eq!(injected, 2);
        }
    });
}

#[test]
fn chaos_same_seed_rerun_injects_identical_fault_sequence() {
    serialized(|| {
        let Some(dir) = manifest_dir() else { return };
        let (addr, coord, worker) =
            spawn_server(dir, EngineConfig::default(), ServerConfig::default());
        // Submits from one sequential client hit the site in a fixed
        // order, so the prob schedule's fire set is a pure function of
        // the seed — two runs must inject the identical sequence.
        let run = |addr: &str| -> Vec<(&'static str, u64)> {
            failpoint::reset();
            failpoint::configure("router.submit=err:prob(0.5,2024)").expect("chaos spec parses");
            let mut slot = Some(Client::connect(addr).expect("connect"));
            for r in 0..16u64 {
                let mut wr = WireRequest::new(r + 1, PROMPT, 2);
                wr.seed = r;
                let (outcome, _retries) =
                    generate_with_retry(addr, &mut slot, &wr, &ADMISSION_RETRY)
                        .expect("retry loop");
                match outcome {
                    GenOutcome::Done { .. } => {}
                    GenOutcome::Rejected(e) => panic!("request {r} rejected: {e:?}"),
                }
            }
            let log = failpoint::take_fired_log();
            failpoint::reset();
            log
        };
        let first = run(&addr);
        let second = run(&addr);
        assert_eq!(first, second, "same seed must inject the identical fault sequence");
        assert!(!first.is_empty(), "prob(0.5) over 16+ submits should have fired");

        let j = await_quiescence(&addr, "router.submit prob(0.5,2024) rerun");
        assert_leak_free(&j, "router.submit prob(0.5,2024) rerun");
        stop_server(&addr, coord, worker);
    });
}

// ---------------------------------------------------------------------------
// wire-level garbage (no failpoints: raw malformed traffic)

#[test]
fn chaos_smoke_garbage_frames_do_not_kill_the_server() {
    serialized(|| {
        let Some(dir) = manifest_dir() else { return };
        let (addr, coord, worker) =
            spawn_server(dir, EngineConfig::default(), ServerConfig::default());

        // non-UTF-8 bytes: the framing layer errors, the connection closes
        {
            let mut s = TcpStream::connect(&addr).expect("raw connect");
            s.write_all(b"\xff\xfe\x80 not even text\n").expect("garbage write");
            let mut sink = Vec::new();
            let _ = s.try_clone().expect("clone").read_to_end(&mut sink);
        }
        // valid text, not our protocol: bad_frame answer, then close
        {
            let mut s = TcpStream::connect(&addr).expect("raw connect");
            s.write_all(b"who goes there\n").expect("garbage write");
            let mut reply = Vec::new();
            let _ = s.try_clone().expect("clone").read_to_end(&mut reply);
            let reply = String::from_utf8_lossy(&reply);
            assert!(reply.contains("bad_frame"), "want a typed bad_frame answer, got {reply:?}");
        }
        // an unterminated flood past the frame cap: typed answer, close
        {
            let mut s = TcpStream::connect(&addr).expect("raw connect");
            let chunk = vec![b'x'; 1 << 16];
            let mut wrote = 0usize;
            while wrote <= MAX_FRAME_LEN + (1 << 16) {
                if s.write_all(&chunk).is_err() {
                    break; // server already hung up on us
                }
                wrote += chunk.len();
            }
            let mut sink = Vec::new();
            let _ = s.try_clone().expect("clone").read_to_end(&mut sink);
        }
        // a truncated frame followed by an abrupt disconnect
        {
            let mut s = TcpStream::connect(&addr).expect("raw connect");
            s.write_all(b"{\"op\":\"hel").expect("partial write");
        }

        // the server is still healthy and leak-free
        let mut c = Client::connect(&addr).expect("healthy connect after garbage");
        match c.generate(&WireRequest::new(1, PROMPT, 4)).expect("healthy request") {
            GenOutcome::Done { events } => assert!(
                matches!(last_event(&events), WireEvent::Finished(_)),
                "healthy request did not finish: {:?}",
                last_event(&events)
            ),
            GenOutcome::Rejected(e) => panic!("healthy request rejected: {e:?}"),
        }
        let j = await_quiescence(&addr, "garbage-frame smoke");
        assert_leak_free(&j, "garbage-frame smoke");
        stop_server(&addr, coord, worker);
    });
}
