//! Shutdown/disconnect race stress for the TCP serving stack.
//!
//! The scenario the pool-accounting fix (removal-tied `InflightGauge`
//! release in `server/conn.rs`) exists for: many clients streaming
//! long generations, some vanishing mid-stream at the same moment a
//! `shutdown` control frame lands. The server must wind down cleanly
//! (no panic, no wedged join) and the engine must end with zero live
//! sequences and zero cache blocks in use.
//!
//! Needs artifacts/ and skips gracefully without it — same convention
//! as server_wire_tests.rs.

use recalkv::artifacts::Manifest;
use recalkv::coordinator::{Coordinator, Engine, EngineConfig};
use recalkv::server::{
    Client, ClientFrame, GenOutcome, Server, ServerConfig, ServerFrame, WireErrorKind,
    WireEvent, WireRequest,
};
use std::path::PathBuf;
use std::sync::{Arc, Barrier};

fn manifest_dir() -> Option<PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] artifacts/ not built");
        return None;
    }
    Some(dir)
}

fn spawn_server(
    dir: PathBuf,
    ecfg: EngineConfig,
    scfg: ServerConfig,
) -> (String, Coordinator, std::thread::JoinHandle<anyhow::Result<()>>) {
    let coord = Coordinator::spawn(move || {
        let man = Manifest::load(&dir)?;
        let rt = recalkv::runtime::Runtime::cpu()?;
        let model = man.model("tiny-mha")?;
        Engine::new(&rt, model, model.variant("recal@50")?, ecfg)
    });
    let server = Server::bind("127.0.0.1:0", coord.handle(), scfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let worker = std::thread::spawn(move || server.run());
    (addr, coord, worker)
}

#[test]
fn disconnect_storm_during_shutdown_reclaims_everything() {
    let Some(dir) = manifest_dir() else { return };
    let (addr, coord, worker) =
        spawn_server(dir, EngineConfig::default(), ServerConfig::default());

    // 6 clients, each streaming a long generation. All of them first prove
    // the request is live (>= 1 token observed), then rendezvous on the
    // barrier with the shutdown sender — so the abrupt socket drops land
    // concurrently with the shutdown frame, not safely before it.
    const CLIENTS: usize = 6;
    let barrier = Arc::new(Barrier::new(CLIENTS + 1));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("stress client connect");
                client
                    .send(&ClientFrame::Gen(WireRequest::new(
                        c as u64 + 1,
                        "the dog barks . the cat sleeps . ",
                        400,
                    )))
                    .expect("stress submit");
                let mut tokens = 0usize;
                while tokens < 1 {
                    match client.recv().expect("stream before shutdown") {
                        ServerFrame::Event(WireEvent::Token { .. }) => tokens += 1,
                        ServerFrame::Event(ev) => {
                            assert!(!ev.is_terminal(), "ended before the race window: {ev:?}")
                        }
                        other => panic!("unexpected frame {other:?}"),
                    }
                }
                barrier.wait();
                // Half the clients vanish abruptly mid-stream (socket drop,
                // no cancel frame); the other half keep reading until the
                // server winds them down, tolerating whatever the teardown
                // order delivers (terminal event, then EOF).
                if c % 2 == 0 {
                    drop(client);
                } else {
                    while let Ok(frame) = client.recv() {
                        if let ServerFrame::Event(ev) = frame {
                            if ev.is_terminal() {
                                break;
                            }
                        }
                    }
                }
            })
        })
        .collect();

    barrier.wait();
    let mut c = Client::connect(&addr).expect("shutdown connection");
    c.shutdown_server().expect("shutdown handshake");
    worker
        .join()
        .expect("server thread panicked during the disconnect storm")
        .expect("server run returned an error");
    for h in handles {
        h.join().expect("stress client panicked");
    }

    // The coordinator outlives the server: every sequence and cache block
    // claimed by the storm must be back.
    let stats = coord.handle().stats().expect("coordinator alive after server shutdown");
    assert_eq!(stats.live_seqs, 0, "shutdown leaked sequences: {stats:?}");
    assert_eq!(stats.blocks_in_use, 0, "shutdown leaked cache blocks: {stats:?}");
    coord.shutdown().expect("coordinator shutdown");
}

#[test]
fn rejected_submits_do_not_leak_the_global_inflight_cap() {
    let Some(dir) = manifest_dir() else { return };
    // Tiny cache budget so oversized requests are rejected typed
    // (`too_large`) by the engine AFTER the wire layer has claimed a
    // global in-flight slot. Before the removal-tied release, each
    // rejection leaked one slot; with the global cap at 2, two rejections
    // would wedge the server into answering queue_full forever.
    let (addr, coord, worker) = spawn_server(
        dir,
        EngineConfig { max_cache_tokens: 16, ..Default::default() },
        ServerConfig { max_inflight_per_conn: 64, max_inflight_global: 2, ..Default::default() },
    );
    let mut client = Client::connect(&addr).unwrap();
    for round in 0..6u64 {
        match client.generate(&WireRequest::new(100 + round, "way past the budget", 64)).unwrap()
        {
            GenOutcome::Rejected(e) => assert!(
                matches!(e.kind, WireErrorKind::TooLarge { .. }),
                "round {round}: want too_large, got {e:?} — a queue_full here means \
                 rejections are leaking the global in-flight cap"
            ),
            GenOutcome::Done { .. } => panic!("oversized request was admitted"),
        }
    }
    // an in-budget request still gets one of the 2 slots (12 + 4 = 16)
    match client.generate(&WireRequest::new(1, "twelve bytes", 4)).unwrap() {
        GenOutcome::Done { events } => {
            let (last, _) = events.last().expect("no events for the in-budget request");
            assert!(matches!(last, WireEvent::Finished(_)), "did not finish: {last:?}");
        }
        GenOutcome::Rejected(e) => {
            panic!("in-budget request rejected after rejections: {e:?} — global cap leaked")
        }
    }
    let mut c = Client::connect(&addr).expect("shutdown connection");
    c.shutdown_server().expect("shutdown handshake");
    worker.join().expect("server thread panicked").expect("server run failed");
    coord.shutdown().expect("coordinator shutdown");
}
