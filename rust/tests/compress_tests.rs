//! Algorithmic invariants of the rust compression mirror + JSON substrate.

use recalkv::compress::{cka, compress_layer, reorder, svdc, LayerInputs, MethodCfg};
use recalkv::linalg::Matrix;
use recalkv::prop_assert;
use recalkv::util::json::Json;
use recalkv::util::prop::check;
use recalkv::util::rng::Rng;

fn layer_inputs(rng: &mut Rng, d: usize, h: usize, dh: usize)
    -> (Matrix, Matrix, Matrix, Matrix, Matrix, Matrix) {
    let wq = Matrix::from_fn(d, h * dh, |_, _| rng.normal() * 0.1);
    let wk = Matrix::from_fn(d, h * dh, |_, _| rng.normal() * 0.1);
    let wv = Matrix::from_fn(d, h * dh, |_, _| rng.normal() * 0.1);
    let wo = Matrix::from_fn(h * dh, d, |_, _| rng.normal() * 0.1);
    let x = Matrix::from_fn(3 * d, d, |_, _| rng.normal());
    let m = x.gram();
    (wq, wk, wv, wo, x, m)
}

#[test]
fn hsr_improves_grouped_svd_error() {
    // Planted structure: heads {0,2} and {1,3} share subspaces. Reordering
    // must group them and reduce the grouped-SVD reconstruction error vs the
    // identity order — the core claim of paper §3.2.
    let mut rng = Rng::new(71);
    let d = 24;
    let dh = 6;
    let base_a = Matrix::from_fn(d, dh, |_, _| rng.normal());
    let base_b = Matrix::from_fn(d, dh, |_, _| rng.normal());
    let noise = |rng: &mut Rng| Matrix::from_fn(d, dh, |_, _| rng.normal() * 0.05);
    let h0 = base_a.add(&noise(&mut rng));
    let h1 = base_b.add(&noise(&mut rng));
    let h2 = base_a.scale(0.9).add(&noise(&mut rng));
    let h3 = base_b.scale(1.1).add(&noise(&mut rng));
    let wk = Matrix::hcat(&[&h0, &h1, &h2, &h3]);
    let x = Matrix::from_fn(128, d, |_, _| rng.normal());
    let sim = cka::head_similarity(&x, &wk, 4);
    let perm = reorder::greedy_group_heads(&sim, 2);
    // similar heads must land together
    let find = |h: usize| perm.iter().position(|p| *p == h).unwrap() / 2;
    assert_eq!(find(0), find(2), "heads 0,2 should share a group: {perm:?}");
    assert_eq!(find(1), find(3), "heads 1,3 should share a group: {perm:?}");

    let rank = 5;
    let ident: Vec<usize> = (0..4).collect();
    let err = |p: &[usize]| {
        let (l, rs) = svdc::grouped_svd(&wk, p, 2, rank, dh, None, 0.0).unwrap();
        let mut total = 0.0;
        for (j, r) in rs.iter().enumerate() {
            let lg = l.cols_slice(j * rank, (j + 1) * rank);
            let cols: Vec<Matrix> = p[j * 2..(j + 1) * 2]
                .iter()
                .map(|c| wk.cols_slice(c * dh, (c + 1) * dh))
                .collect();
            let refs: Vec<&Matrix> = cols.iter().collect();
            let wg = Matrix::hcat(&refs);
            total += wg.sub(&lg.matmul(r)).frob_sq();
        }
        total
    };
    let e_reordered = err(&perm);
    let e_identity = err(&ident);
    assert!(
        e_reordered < e_identity,
        "HSR should reduce error: {e_reordered} vs {e_identity}"
    );
}

#[test]
fn calibration_never_hurts_property() {
    check("calibration_monotone", 10, |ctx| {
        let mut rng = Rng::new(ctx.seed);
        let d = 8 + ctx.usize_in(0, 8);
        let n = d + 4;
        let w = Matrix::from_fn(d, n, |_, _| rng.normal());
        let x = Matrix::from_fn(4 * d, d, |_, _| rng.normal());
        let m = x.gram();
        let r = (d / 2).max(2);
        let (l0, r0) = svdc::svd_lowrank(&w, r);
        let (_, _, hist) =
            recalkv::compress::calibrate::calibrate(&w, &l0, &r0, &m, 6, 1e-9)
                .map_err(|e| e.to_string())?;
        for win in hist.windows(2) {
            prop_assert!(win[1] <= win[0] * 1.00001, "error increased: {hist:?}");
        }
        Ok(())
    });
}

#[test]
fn whitening_never_hurts_in_data_metric() {
    check("whitening_optimal", 8, |ctx| {
        let mut rng = Rng::new(ctx.seed);
        let d = 10;
        let n = 14;
        let w = Matrix::from_fn(d, n, |_, _| rng.normal());
        // anisotropic data
        let mut x = Matrix::from_fn(80, d, |_, _| rng.normal() * 0.2);
        for i in 0..x.rows {
            x[(i, 0)] += rng.normal() * 3.0;
        }
        let m = x.gram();
        let r = 4;
        let (lp, rp) = svdc::svd_lowrank(&w, r);
        let (lw, rw) = svdc::whitened_svd_lowrank(&w, r, &m, 1e-4).map_err(|e| e.to_string())?;
        let ep = svdc::recon_error(&w, &lp, &rp, Some(&m));
        let ew = svdc::recon_error(&w, &lw, &rw, Some(&m));
        prop_assert!(ew <= ep * 1.01, "whitened {ew} worse than plain {ep}");
        Ok(())
    });
}

#[test]
fn methods_ordering_on_synthetic_layer() {
    // End-to-end layer compression: recal must beat palu in data-aware
    // value error (its whole point), on anisotropic calibration data.
    let mut rng = Rng::new(77);
    let (wq, wk, wv, wo, _x, _) = layer_inputs(&mut rng, 24, 4, 6);
    let mut x = Matrix::from_fn(200, 24, |_, _| rng.normal() * 0.3);
    for i in 0..x.rows {
        x[(i, 1)] += rng.normal() * 2.5;
    }
    let m = x.gram();
    let inp = |key_rank, value_rank| LayerInputs {
        w_q: &wq, w_k: &wk, w_v: &wv, w_o: &wo, m: &m, x_sample: &x,
        n_heads: 4, n_kv_heads: 4, d_head: 6, group_size: 2,
        key_rank, value_rank,
    };
    let recal = compress_layer(&inp(4, 8), MethodCfg::from_name("recal").unwrap()).unwrap();
    let palu = compress_layer(&inp(4, 8), MethodCfg::from_name("palu").unwrap()).unwrap();
    assert!(
        recal.value_error_post <= palu.value_error_post,
        "recal value error {} should be <= palu {}",
        recal.value_error_post,
        palu.value_error_post
    );
    assert!(
        recal.key_error <= palu.key_error * 1.05,
        "recal key error {} should be <= palu-ish {}",
        recal.key_error,
        palu.key_error
    );
}

#[test]
fn json_roundtrip_property() {
    check("json_roundtrip", 40, |ctx| {
        // build a random JSON value and round-trip it
        fn build(ctx: &mut recalkv::util::prop::PropCtx, depth: usize) -> Json {
            match if depth == 0 { ctx.rng.below(4) } else { ctx.rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(ctx.rng.below(2) == 0),
                2 => Json::Num((ctx.rng.below(100000) as f64) / 8.0 - 1000.0),
                3 => Json::Str(format!("s{}\n\"x\"{}", ctx.rng.below(100), ctx.rng.below(10))),
                4 => Json::Arr((0..ctx.rng.below(4)).map(|_| build(ctx, depth - 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..ctx.rng.below(4) {
                        m.insert(format!("k{i}"), build(ctx, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = build(ctx, 3);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| e)?;
        prop_assert!(back == v, "roundtrip mismatch: {text}");
        Ok(())
    });
}
