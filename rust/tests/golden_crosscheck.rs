//! Cross-language goldens: the rust substrates must reproduce what the
//! python build produced — corpus/task generation byte-for-byte, the
//! compression pipeline numerically, and quantization bit-for-bit.
//!
//! These tests are skipped (pass trivially with a notice) when artifacts/
//! has not been built yet, so `cargo test` works on a fresh checkout.

use recalkv::artifacts::TensorArchive;
use recalkv::compress::{compress_layer, LayerInputs, MethodCfg};
use recalkv::eval::tasks;
use recalkv::linalg::Matrix;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts/ not built — run `make artifacts` first");
        None
    }
}

#[test]
fn corpus_splits_match_python() {
    let Some(dir) = artifacts_dir() else { return };
    let g = TensorArchive::load(dir.join("corpus_goldens.rtz")).unwrap();
    for split in ["wiki", "ptb", "c4"] {
        let want = &g.get(&format!("split.{split}")).unwrap().i32s;
        let got = tasks::ppl_split(split, 42, 1024);
        assert_eq!(&got, want, "split {split} diverges from python");
    }
}

#[test]
fn mc_instances_match_python() {
    let Some(dir) = artifacts_dir() else { return };
    let g = TensorArchive::load(dir.join("corpus_goldens.rtz")).unwrap();
    for task in tasks::MC_TASKS {
        let instances = tasks::gen_mc(task, 42, 3);
        for (i, inst) in instances.iter().enumerate() {
            let ctx: Vec<i32> = inst.context.bytes().map(|b| b as i32).collect();
            let want_ctx = &g.get(&format!("mc.{task}.{i}.context")).unwrap().i32s;
            assert_eq!(&ctx, want_ctx, "mc {task}[{i}] context");
            let choices: Vec<i32> = inst.choices.join("|").bytes().map(|b| b as i32).collect();
            let want_ch = &g.get(&format!("mc.{task}.{i}.choices")).unwrap().i32s;
            assert_eq!(&choices, want_ch, "mc {task}[{i}] choices");
            let want_ans = g.get(&format!("mc.{task}.{i}.answer")).unwrap().i32s[0] as usize;
            assert_eq!(inst.answer, want_ans, "mc {task}[{i}] answer");
        }
    }
}

#[test]
fn long_instances_match_python() {
    let Some(dir) = artifacts_dir() else { return };
    let g = TensorArchive::load(dir.join("corpus_goldens.rtz")).unwrap();
    for task in tasks::LONG_TASKS {
        let inst = &tasks::gen_long(task, 42, 1, 200)[0];
        let prompt: Vec<i32> = inst.prompt.bytes().map(|b| b as i32).collect();
        let want = &g.get(&format!("long.{task}.prompt")).unwrap().i32s;
        assert_eq!(&prompt, want, "long {task} prompt");
        let exp: Vec<i32> = inst.expected.bytes().map(|b| b as i32).collect();
        let want_e = &g.get(&format!("long.{task}.expected")).unwrap().i32s;
        assert_eq!(&exp, want_e, "long {task} expected");
    }
}

#[test]
fn quant_matches_python_bit_for_bit() {
    let Some(dir) = artifacts_dir() else { return };
    let g = TensorArchive::load(dir.join("tiny-mha/goldens.rtz")).unwrap();
    let x = g.get("quant.x").unwrap();
    let signs = g.f32s("quant.signs").unwrap();
    let n = x.dims[1];
    for bits in [4u32, 3] {
        let want_q = &g.get(&format!("quant.q{bits}")).unwrap().i32s;
        let want_s = g.f32s(&format!("quant.scale{bits}")).unwrap();
        let kind = if bits == 4 {
            recalkv::quant::QuantKind::Int4
        } else {
            recalkv::quant::QuantKind::Int3
        };
        for (t, row) in x.f32s.chunks_exact(n).enumerate() {
            let q = recalkv::quant::quantize(row, signs, kind);
            assert!(
                (q.scale - want_s[t]).abs() <= 1e-6 * want_s[t].abs().max(1e-6),
                "scale row {t} bits {bits}: {} vs {}",
                q.scale,
                want_s[t]
            );
            let mut back = vec![0.0f32; n];
            recalkv::quant::dequantize(&q, signs, &mut back);
            // python dequant of python's own codes must agree exactly
            let py_codes = &want_q[t * n..(t + 1) * n];
            let mut py_row: Vec<f32> = py_codes.iter().map(|c| *c as f32 * want_s[t]).collect();
            recalkv::linalg::hadamard::inverse(&mut py_row, signs);
            for (a, b) in back.iter().zip(&py_row) {
                assert!((a - b).abs() < 1e-5, "bits {bits} row {t}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn rust_pipeline_reproduces_python_layer0() {
    let Some(dir) = artifacts_dir() else { return };
    let g = TensorArchive::load(dir.join("tiny-mha/goldens.rtz")).unwrap();
    let to_m = |name: &str| {
        let t = g.get(name).unwrap();
        Matrix::from_vec(t.dims[0], t.dims[1], t.f32s.clone())
    };
    let w_q = to_m("w_q0");
    let w_k = to_m("w_k0");
    let w_v = to_m("w_v0");
    let w_o = to_m("w_o0");
    let m = to_m("m0");
    let x = to_m("x_sample0");
    let key_ranks = &g.get("key_ranks").unwrap().i32s;
    let value_ranks = &g.get("value_ranks").unwrap().i32s;
    let inp = LayerInputs {
        w_q: &w_q, w_k: &w_k, w_v: &w_v, w_o: &w_o, m: &m, x_sample: &x,
        n_heads: 8, n_kv_heads: 8, d_head: 32, group_size: 4,
        key_rank: key_ranks[0] as usize,
        value_rank: value_ranks[0] as usize,
    };
    let out = compress_layer(&inp, MethodCfg::from_name("recal").unwrap()).unwrap();

    // 1. CKA similarity matrix matches python's
    let want_cka = to_m("cka0");
    let diff = out.cka.max_abs_diff(&want_cka);
    assert!(diff < 5e-3, "cka matrix diverges: {diff}");

    // 2. head permutation identical
    let want_perm: Vec<usize> =
        g.get("perm0").unwrap().i32s.iter().map(|v| *v as usize).collect();
    assert_eq!(out.kv_perm, want_perm, "HSR permutation diverges");

    // 3. factors span the same subspace: compare *reconstructions* (SVD
    //    sign/rotation freedom makes raw factor comparison meaningless)
    let want_lk = to_m("Lk0");
    let want_rk_t = g.get("Rk0").unwrap();
    let rk = key_ranks[0] as usize;
    let sdh = want_rk_t.dims[2];
    for grp in 0..2usize {
        let l_py = want_lk.cols_slice(grp * rk, (grp + 1) * rk);
        let l_rs = out.l_k.cols_slice(grp * rk, (grp + 1) * rk);
        let r_py = Matrix::from_vec(
            rk, sdh,
            want_rk_t.f32s[grp * rk * sdh..(grp + 1) * rk * sdh].to_vec());
        let rec_py = l_py.matmul(&r_py);
        let rec_rs = l_rs.matmul(&out.r_k[grp]);
        let scale = rec_py.frob_sq().sqrt().max(1e-9);
        let d = rec_py.sub(&rec_rs).frob_sq().sqrt() / scale;
        assert!(d < 2e-2, "group {grp} key reconstruction diverges: rel {d}");
    }

    // 4. value path quality: the calibration problem has many optimal
    //    solutions (full/near-full rank ⇒ degenerate), so compare each
    //    implementation against the TRUE uncompressed path
    //    Σ_h W_v[:, kv(h)-block] · W_o[h-block] rather than to each other.
    let truth = {
        let mut acc = Matrix::zeros(w_v.rows, w_o.cols);
        for h in 0..8usize {
            let vblk = w_v.cols_slice(h * 32, (h + 1) * 32);
            let mut oblk = Matrix::zeros(32, w_o.cols);
            for r in 0..32 {
                oblk.row_mut(r).copy_from_slice(w_o.row(h * 32 + r));
            }
            acc = acc.add(&vblk.matmul(&oblk));
        }
        acc
    };
    let py_map = lv_path_signature(&to_m("Lv0"), &to_m("wo_fused0"), 8);
    let rs_map = lv_path_signature(&out.l_v, &out.wo_fused, 8);
    let scale = truth.frob_sq().sqrt().max(1e-9);
    let py_err = py_map.sub(&truth).frob_sq().sqrt() / scale;
    let rs_err = rs_map.sub(&truth).frob_sq().sqrt() / scale;
    assert!(
        rs_err <= py_err * 1.5 + 2e-2,
        "rust value path quality {rs_err} much worse than python {py_err}"
    );
}

/// Σ_h L_v · W̃_o[h-th block] — collapses the value path to a [d, d] map
/// that is invariant to the SVD rotation freedom.
fn lv_path_signature(l_v: &Matrix, wo_fused: &Matrix, n_heads: usize) -> Matrix {
    let rv = l_v.cols;
    let d_out = wo_fused.cols;
    let mut acc = Matrix::zeros(l_v.rows, d_out);
    for h in 0..n_heads {
        let mut blk = Matrix::zeros(rv, d_out);
        for r in 0..rv {
            blk.row_mut(r).copy_from_slice(wo_fused.row(h * rv + r));
        }
        acc = acc.add(&l_v.matmul(&blk));
    }
    acc
}

#[test]
fn rtz_python_archive_loads() {
    let Some(dir) = artifacts_dir() else { return };
    let a = TensorArchive::load(dir.join("tiny-mha/weights.rtz")).unwrap();
    let embed = a.get("embed").unwrap();
    assert_eq!(embed.dims, vec![256, 256]);
    assert!(embed.f32s.iter().all(|v| v.is_finite()));
    assert!(a.tensors.len() > 30);
}
