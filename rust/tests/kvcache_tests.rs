//! Paged-cache invariants under randomized workloads (property-style).

use recalkv::kvcache::{CacheConfig, KvCache};
use recalkv::prop_assert;
use recalkv::quant::QuantKind;
use recalkv::util::prop::check;

fn cfg(quant: QuantKind, widths: Vec<(usize, usize)>, cap: usize) -> CacheConfig {
    CacheConfig {
        n_layers: widths.len(),
        widths,
        cache_len: 128,
        tokens_per_block: 8,
        capacity_tokens: cap,
        quant,
        signs_seed: 13,
    }
}

#[test]
fn random_append_stage_consistency() {
    check("cache_append_stage", 10, |ctx| {
        let widths = vec![(8usize, 12usize), (16, 4)];
        let mut cache = KvCache::new(cfg(QuantKind::F32, widths.clone(), 4096));
        let n_seqs = 1 + ctx.usize_in(1, 4);
        let seqs: Vec<_> = (0..n_seqs).map(|_| cache.new_seq()).collect();
        let mut mirror: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n_seqs]; // [seq][token] k-plane L0
        for _ in 0..ctx.usize_in(5, 60) {
            let si = ctx.rng.below(n_seqs);
            let k0 = ctx.f32_vec(8, 1.0);
            let v0 = ctx.f32_vec(12, 1.0);
            let k1 = ctx.f32_vec(16, 1.0);
            let v1 = ctx.f32_vec(4, 1.0);
            if cache.seq_len(seqs[si]) >= 128 {
                continue;
            }
            cache
                .append(seqs[si], &[(&k0, &v0), (&k1, &v1)])
                .map_err(|e| e.to_string())?;
            mirror[si].push(k0);
        }
        for si in 0..n_seqs {
            let len = cache.seq_len(seqs[si]);
            prop_assert!(len == mirror[si].len(), "length mismatch");
            let mut out = vec![0.0; 128 * 8];
            cache.stage(seqs[si], 0, 0, &mut out, 128).map_err(|e| e.to_string())?;
            for (t, want) in mirror[si].iter().enumerate() {
                let got = &out[t * 8..(t + 1) * 8];
                prop_assert!(got == &want[..], "row {t} differs for seq {si}");
            }
            for v in &out[len * 8..] {
                prop_assert!(*v == 0.0, "padding not zeroed");
            }
        }
        Ok(())
    });
}

#[test]
fn free_always_returns_all_blocks() {
    check("cache_free_blocks", 10, |ctx| {
        let mut cache = KvCache::new(cfg(QuantKind::F32, vec![(4, 4)], 2048));
        let mut live = Vec::new();
        for _ in 0..ctx.usize_in(2, 20) {
            let s = cache.new_seq();
            let n = ctx.usize_in(1, 30);
            for _ in 0..n {
                let k = ctx.f32_vec(4, 1.0);
                let v = ctx.f32_vec(4, 1.0);
                cache.append(s, &[(&k, &v)]).map_err(|e| e.to_string())?;
            }
            live.push(s);
            // randomly free one
            if ctx.rng.below(3) == 0 && !live.is_empty() {
                let i = ctx.rng.below(live.len());
                cache.free_seq(live.swap_remove(i));
            }
        }
        for s in live {
            cache.free_seq(s);
        }
        prop_assert!(cache.blocks_in_use() == 0, "leaked blocks");
        prop_assert!(cache.total_tokens() == 0, "leaked tokens");
        Ok(())
    });
}

#[test]
fn quantized_stage_error_bounded() {
    check("cache_quant_error", 8, |ctx| {
        for quant in [QuantKind::Int4, QuantKind::Int3] {
            let mut cache = KvCache::new(cfg(quant, vec![(16, 16)], 1024));
            let s = cache.new_seq();
            let mut rows = Vec::new();
            for _ in 0..10 {
                let k = ctx.f32_vec(16, 1.0);
                cache.append(s, &[(&k, &k)]).map_err(|e| e.to_string())?;
                rows.push(k);
            }
            let mut out = vec![0.0; 128 * 16];
            cache.stage(s, 0, 0, &mut out, 128).map_err(|e| e.to_string())?;
            // error bounded by ~2·amax/qmax per element in rotated space
            let qmax = quant.qmax() as f32;
            for (t, want) in rows.iter().enumerate() {
                let amax = want.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let bound = 3.0 * amax / qmax + 1e-3;
                for (a, b) in want.iter().zip(&out[t * 16..(t + 1) * 16]) {
                    prop_assert!(
                        (a - b).abs() <= bound,
                        "{quant:?} err {} > bound {bound}",
                        (a - b).abs()
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn bytes_per_token_accounting() {
    // the paper's memory claim: compressed+quantized cache is dramatically
    // smaller than the full fp32 cache
    let full = cfg(QuantKind::F32, vec![(256, 256); 4], 16).bytes_per_token();
    let low = cfg(QuantKind::F32, vec![(64, 96); 4], 16).bytes_per_token();
    let low4 = cfg(QuantKind::Int4, vec![(64, 96); 4], 16).bytes_per_token();
    assert_eq!(full, 4 * (256 + 256) * 4);
    assert_eq!(low, 4 * (64 + 96) * 4);
    assert!(low4 < low / 6, "int4 should be ~8x smaller: {low4} vs {low}");
}
