//! Paged-cache invariants under randomized workloads (property-style).

use recalkv::kvcache::{CacheConfig, KvCache};
use recalkv::prop_assert;
use recalkv::quant::QuantKind;
use recalkv::util::prop::check;

fn cfg(quant: QuantKind, widths: Vec<(usize, usize)>, cap: usize) -> CacheConfig {
    CacheConfig {
        n_layers: widths.len(),
        widths,
        cache_len: 128,
        tokens_per_block: 8,
        capacity_tokens: cap,
        quant,
        signs_seed: 13,
    }
}

#[test]
fn random_append_stage_consistency() {
    check("cache_append_stage", 10, |ctx| {
        let widths = vec![(8usize, 12usize), (16, 4)];
        let mut cache = KvCache::new(cfg(QuantKind::F32, widths.clone(), 4096));
        let n_seqs = 1 + ctx.usize_in(1, 4);
        let seqs: Vec<_> = (0..n_seqs).map(|_| cache.new_seq()).collect();
        let mut mirror: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n_seqs]; // [seq][token] k-plane L0
        for _ in 0..ctx.usize_in(5, 60) {
            let si = ctx.rng.below(n_seqs);
            let k0 = ctx.f32_vec(8, 1.0);
            let v0 = ctx.f32_vec(12, 1.0);
            let k1 = ctx.f32_vec(16, 1.0);
            let v1 = ctx.f32_vec(4, 1.0);
            if cache.seq_len(seqs[si]) >= 128 {
                continue;
            }
            cache
                .append(seqs[si], &[(&k0, &v0), (&k1, &v1)])
                .map_err(|e| e.to_string())?;
            mirror[si].push(k0);
        }
        for si in 0..n_seqs {
            let len = cache.seq_len(seqs[si]);
            prop_assert!(len == mirror[si].len(), "length mismatch");
            let mut out = vec![0.0; 128 * 8];
            cache.stage(seqs[si], 0, 0, &mut out, 128).map_err(|e| e.to_string())?;
            for (t, want) in mirror[si].iter().enumerate() {
                let got = &out[t * 8..(t + 1) * 8];
                prop_assert!(got == &want[..], "row {t} differs for seq {si}");
            }
            for v in &out[len * 8..] {
                prop_assert!(*v == 0.0, "padding not zeroed");
            }
        }
        Ok(())
    });
}

#[test]
fn free_always_returns_all_blocks() {
    check("cache_free_blocks", 10, |ctx| {
        let mut cache = KvCache::new(cfg(QuantKind::F32, vec![(4, 4)], 2048));
        let mut live = Vec::new();
        for _ in 0..ctx.usize_in(2, 20) {
            let s = cache.new_seq();
            let n = ctx.usize_in(1, 30);
            for _ in 0..n {
                let k = ctx.f32_vec(4, 1.0);
                let v = ctx.f32_vec(4, 1.0);
                cache.append(s, &[(&k, &v)]).map_err(|e| e.to_string())?;
            }
            live.push(s);
            // randomly free one
            if ctx.rng.below(3) == 0 && !live.is_empty() {
                let i = ctx.rng.below(live.len());
                cache.free_seq(live.swap_remove(i));
            }
        }
        for s in live {
            cache.free_seq(s);
        }
        prop_assert!(cache.blocks_in_use() == 0, "leaked blocks");
        prop_assert!(cache.total_tokens() == 0, "leaked tokens");
        Ok(())
    });
}

#[test]
fn quantized_stage_error_bounded() {
    check("cache_quant_error", 8, |ctx| {
        for quant in [QuantKind::Int4, QuantKind::Int3] {
            let mut cache = KvCache::new(cfg(quant, vec![(16, 16)], 1024));
            let s = cache.new_seq();
            let mut rows = Vec::new();
            for _ in 0..10 {
                let k = ctx.f32_vec(16, 1.0);
                cache.append(s, &[(&k, &k)]).map_err(|e| e.to_string())?;
                rows.push(k);
            }
            let mut out = vec![0.0; 128 * 16];
            cache.stage(s, 0, 0, &mut out, 128).map_err(|e| e.to_string())?;
            // error bounded by ~2·amax/qmax per element in rotated space
            let qmax = quant.qmax() as f32;
            for (t, want) in rows.iter().enumerate() {
                let amax = want.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let bound = 3.0 * amax / qmax + 1e-3;
                for (a, b) in want.iter().zip(&out[t * 16..(t + 1) * 16]) {
                    prop_assert!(
                        (a - b).abs() <= bound,
                        "{quant:?} err {} > bound {bound}",
                        (a - b).abs()
                    );
                }
            }
        }
        Ok(())
    });
}

/// The engine's incremental staging protocol, replayed at the cache level:
/// per-(layer, plane) buffers are written by `append_and_stage` tail writes
/// (with occasional plain `append`s caught up via `stage_rows`, the
/// quantized-mode fallback) and must stay bit-identical to a fresh full
/// `stage()` gather after every step — in both F32 and Int4 modes.
#[test]
fn incremental_staging_protocol_equivalence() {
    fn compare(cache: &KvCache, seq: u64, layer: usize, plane: usize, w: usize,
               buf: &[f32], quant: QuantKind, step: usize) -> Result<(), String> {
        let mut fresh = vec![0.0f32; 128 * w];
        cache.stage(seq, layer, plane, &mut fresh, 128).map_err(|e| e.to_string())?;
        prop_assert!(
            buf.iter().zip(&fresh).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{quant:?} step {step}: layer {layer} plane {plane} not bit-identical"
        );
        Ok(())
    }

    check("incremental_staging_equiv", 8, |ctx| {
        for quant in [QuantKind::F32, QuantKind::Int4] {
            let widths = vec![(8usize, 12usize), (16, 4)];
            let mut cache = KvCache::new(cfg(quant, widths, 4096));
            let seq = cache.new_seq();
            let mut b00 = vec![0.0f32; 128 * 8];
            let mut b01 = vec![0.0f32; 128 * 12];
            let mut b10 = vec![0.0f32; 128 * 16];
            let mut b11 = vec![0.0f32; 128 * 4];
            let mut staged_len = 0usize;
            let steps = ctx.usize_in(10, 60);
            for step in 0..steps {
                let t = cache.seq_len(seq);
                if t >= 128 {
                    break;
                }
                let k0 = ctx.f32_vec(8, 1.0);
                let v0 = ctx.f32_vec(12, 1.0);
                let k1 = ctx.f32_vec(16, 1.0);
                let v1 = ctx.f32_vec(4, 1.0);
                let rows = [(&k0[..], &v0[..]), (&k1[..], &v1[..])];
                if ctx.rng.below(4) == 0 {
                    // plain append: buffer lags the cache until caught up
                    cache.append(seq, &rows).map_err(|e| e.to_string())?;
                } else {
                    let mut dst = [
                        (&mut b00[t * 8..(t + 1) * 8], &mut b01[t * 12..(t + 1) * 12]),
                        (&mut b10[t * 16..(t + 1) * 16], &mut b11[t * 4..(t + 1) * 4]),
                    ];
                    let pos = cache
                        .append_and_stage(seq, &rows, &mut dst)
                        .map_err(|e| e.to_string())?;
                    prop_assert!(pos == t, "staging offset {pos} != row {t}");
                    // append_and_stage only extends an up-to-date buffer
                    if staged_len == t {
                        staged_len = t + 1;
                    }
                }
                // catch-up: stage only the rows written since the last stage
                let len = cache.seq_len(seq);
                if staged_len < len {
                    for (layer, plane, w, buf) in [
                        (0usize, 0usize, 8usize, &mut b00),
                        (0, 1, 12, &mut b01),
                        (1, 0, 16, &mut b10),
                        (1, 1, 4, &mut b11),
                    ] {
                        cache
                            .stage_rows(seq, layer, plane, staged_len, len,
                                        &mut buf[staged_len * w..len * w])
                            .map_err(|e| e.to_string())?;
                    }
                    staged_len = len;
                }
                compare(&cache, seq, 0, 0, 8, &b00, quant, step)?;
                compare(&cache, seq, 0, 1, 12, &b01, quant, step)?;
                compare(&cache, seq, 1, 0, 16, &b10, quant, step)?;
                compare(&cache, seq, 1, 1, 4, &b11, quant, step)?;
            }
        }
        Ok(())
    });
}

/// Pool exhaustion must leave the cache transactionally consistent: the
/// failing token takes no pages, accounting stays exact, and rows appended
/// after space frees up are read back aligned.
#[test]
fn append_failure_keeps_cache_consistent() {
    check("append_rollback_consistency", 10, |ctx| {
        let cap = 32;
        let mut cache = KvCache::new(cfg(QuantKind::F32, vec![(8, 12), (16, 4)], cap));
        let hog = cache.new_seq();
        let victim = cache.new_seq();
        // hog grabs most of the pool
        let hog_tokens = ctx.usize_in(cap - 8, cap);
        for t in 0..hog_tokens {
            let rows = (ctx.f32_vec(8, 1.0), ctx.f32_vec(12, 1.0),
                        ctx.f32_vec(16, 1.0), ctx.f32_vec(4, 1.0));
            if cache.append(hog, &[(&rows.0, &rows.1), (&rows.2, &rows.3)]).is_err() {
                prop_assert!(t > 0, "pool exhausted before any append");
                break;
            }
        }
        // drive the victim into exhaustion
        let mut victim_rows: Vec<Vec<f32>> = Vec::new();
        let mut failed = false;
        for _ in 0..cap {
            let k0 = ctx.f32_vec(8, 1.0);
            let v0 = ctx.f32_vec(12, 1.0);
            let k1 = ctx.f32_vec(16, 1.0);
            let v1 = ctx.f32_vec(4, 1.0);
            let before_blocks = cache.blocks_in_use();
            let before_tokens = cache.total_tokens();
            let before_len = cache.seq_len(victim);
            match cache.append(victim, &[(&k0, &v0), (&k1, &v1)]) {
                Ok(()) => victim_rows.push(k0),
                Err(_) => {
                    failed = true;
                    // rollback: nothing changed
                    prop_assert!(cache.blocks_in_use() == before_blocks,
                                 "blocks_in_use changed across failed append");
                    prop_assert!(cache.total_tokens() == before_tokens,
                                 "total_tokens changed across failed append");
                    prop_assert!(cache.seq_len(victim) == before_len,
                                 "seq_len changed across failed append");
                    break;
                }
            }
        }
        prop_assert!(failed, "expected the pool to exhaust");
        // free the hog; the victim must append and stage aligned rows
        cache.free_seq(hog);
        let k0 = ctx.f32_vec(8, 1.0);
        let v0 = ctx.f32_vec(12, 1.0);
        let k1 = ctx.f32_vec(16, 1.0);
        let v1 = ctx.f32_vec(4, 1.0);
        cache.append(victim, &[(&k0, &v0), (&k1, &v1)]).map_err(|e| e.to_string())?;
        victim_rows.push(k0);
        let mut out = vec![0.0; 128 * 8];
        cache.stage(victim, 0, 0, &mut out, 128).map_err(|e| e.to_string())?;
        for (t, want) in victim_rows.iter().enumerate() {
            prop_assert!(&out[t * 8..(t + 1) * 8] == &want[..],
                         "row {t} misaligned after rollback + recovery");
        }
        cache.free_seq(victim);
        prop_assert!(cache.blocks_in_use() == 0, "blocks leaked after rollback cycle");
        prop_assert!(cache.total_tokens() == 0, "tokens leaked after rollback cycle");
        Ok(())
    });
}

/// Copy-on-write forks: a forked sequence that diverges must stay
/// bit-identical to an independently built sequence with the same row
/// history, in both F32 and quantized modes — and tearing everything down
/// must leave the pool exactly empty.
#[test]
fn cow_fork_divergence_matches_independent_sequences() {
    check("cow_fork_divergence", 6, |ctx| {
        for quant in [QuantKind::F32, QuantKind::Int4] {
            let mut cache = KvCache::new(cfg(quant, vec![(8, 12), (16, 4)], 4096));
            let shared_len = ctx.usize_in(1, 20);
            let a_extra = ctx.usize_in(1, 10);
            let b_extra = ctx.usize_in(1, 10);
            let mut shared = Vec::new();
            for _ in 0..shared_len {
                shared.push((ctx.f32_vec(8, 1.0), ctx.f32_vec(12, 1.0),
                             ctx.f32_vec(16, 1.0), ctx.f32_vec(4, 1.0)));
            }
            let mut a_tail = Vec::new();
            for _ in 0..a_extra {
                a_tail.push((ctx.f32_vec(8, 1.0), ctx.f32_vec(12, 1.0),
                             ctx.f32_vec(16, 1.0), ctx.f32_vec(4, 1.0)));
            }
            let mut b_tail = Vec::new();
            for _ in 0..b_extra {
                b_tail.push((ctx.f32_vec(8, 1.0), ctx.f32_vec(12, 1.0),
                             ctx.f32_vec(16, 1.0), ctx.f32_vec(4, 1.0)));
            }
            // world 1: shared prefix via fork, then divergent tails (COW)
            let a = cache.new_seq();
            for r in &shared {
                cache.append(a, &[(&r.0, &r.1), (&r.2, &r.3)]).map_err(|e| e.to_string())?;
            }
            let b = cache.fork_seq(a).map_err(|e| e.to_string())?;
            for r in &a_tail {
                cache.append(a, &[(&r.0, &r.1), (&r.2, &r.3)]).map_err(|e| e.to_string())?;
            }
            for r in &b_tail {
                cache.append(b, &[(&r.0, &r.1), (&r.2, &r.3)]).map_err(|e| e.to_string())?;
            }
            // world 2: the same row histories built cold, no sharing
            let c = cache.new_seq();
            for r in shared.iter().chain(&a_tail) {
                cache.append(c, &[(&r.0, &r.1), (&r.2, &r.3)]).map_err(|e| e.to_string())?;
            }
            let d = cache.new_seq();
            for r in shared.iter().chain(&b_tail) {
                cache.append(d, &[(&r.0, &r.1), (&r.2, &r.3)]).map_err(|e| e.to_string())?;
            }
            for (seq, twin) in [(a, c), (b, d)] {
                for (layer, plane, w) in [(0usize, 0usize, 8usize), (0, 1, 12),
                                          (1, 0, 16), (1, 1, 4)] {
                    let mut x = vec![0.0f32; 128 * w];
                    let mut y = vec![0.0f32; 128 * w];
                    cache.stage(seq, layer, plane, &mut x, 128).map_err(|e| e.to_string())?;
                    cache.stage(twin, layer, plane, &mut y, 128).map_err(|e| e.to_string())?;
                    prop_assert!(
                        x.iter().zip(&y).all(|(p, q)| p.to_bits() == q.to_bits()),
                        "{quant:?} layer {layer} plane {plane}: fork lineage not \
                         bit-identical to cold build"
                    );
                }
            }
            for s in [a, b, c, d] {
                cache.free_seq(s);
            }
            prop_assert!(cache.blocks_in_use() == 0, "{quant:?}: leaked blocks");
            prop_assert!(cache.total_tokens() == 0, "{quant:?}: leaked tokens");
        }
        Ok(())
    });
}

/// `free_seq` on a sequence that shares all its pages must release only its
/// refcounts: `blocks_in_use` is unchanged (the fork still owns every
/// page), the survivor reads its rows bit-exactly and can keep appending,
/// and only the last owner's free drains the pool to zero.
#[test]
fn shared_page_free_releases_only_the_refcount() {
    check("shared_free_refcount", 8, |ctx| {
        let mut cache = KvCache::new(cfg(QuantKind::F32, vec![(8, 12), (16, 4)], 2048));
        let n = ctx.usize_in(1, 24);
        let mut rows = Vec::new();
        for _ in 0..n {
            rows.push((ctx.f32_vec(8, 1.0), ctx.f32_vec(12, 1.0),
                       ctx.f32_vec(16, 1.0), ctx.f32_vec(4, 1.0)));
        }
        let a = cache.new_seq();
        for r in &rows {
            cache.append(a, &[(&r.0, &r.1), (&r.2, &r.3)]).map_err(|e| e.to_string())?;
        }
        let b = cache.fork_seq(a).map_err(|e| e.to_string())?;
        let before = cache.blocks_in_use();
        let freed = cache.free_seq(a);
        prop_assert!(freed == 0, "freeing a full sharer reclaimed {freed} pages");
        prop_assert!(cache.blocks_in_use() == before,
                     "freeing a sharer changed blocks_in_use");
        let mut out = vec![0.0f32; 128 * 8];
        cache.stage(b, 0, 0, &mut out, 128).map_err(|e| e.to_string())?;
        for (t, r) in rows.iter().enumerate() {
            prop_assert!(out[t * 8..(t + 1) * 8] == r.0[..],
                         "survivor row {t} corrupted by donor free");
        }
        // the survivor is now sole owner: appends work, and its free drains
        // the pool completely
        let (k0, v0) = (ctx.f32_vec(8, 1.0), ctx.f32_vec(12, 1.0));
        let (k1, v1) = (ctx.f32_vec(16, 1.0), ctx.f32_vec(4, 1.0));
        cache.append(b, &[(&k0, &v0), (&k1, &v1)]).map_err(|e| e.to_string())?;
        cache.free_seq(b);
        prop_assert!(cache.blocks_in_use() == 0, "blocks leaked after last owner freed");
        prop_assert!(cache.total_tokens() == 0, "tokens leaked after last owner freed");
        Ok(())
    });
}

#[test]
fn bytes_per_token_accounting() {
    // the paper's memory claim: compressed+quantized cache is dramatically
    // smaller than the full fp32 cache
    let full = cfg(QuantKind::F32, vec![(256, 256); 4], 16).bytes_per_token();
    let low = cfg(QuantKind::F32, vec![(64, 96); 4], 16).bytes_per_token();
    let low4 = cfg(QuantKind::Int4, vec![(64, 96); 4], 16).bytes_per_token();
    assert_eq!(full, 4 * (256 + 256) * 4);
    assert_eq!(low, 4 * (64 + 96) * 4);
    assert!(low4 < low / 6, "int4 should be ~8x smaller: {low4} vs {low}");
}
