//! Wire-protocol and TCP-server tests.
//!
//! The protocol round-trip properties are runtime-free and always run.
//! The end-to-end server tests (equivalence with the in-process engine,
//! cancel-on-disconnect page reclamation, wire backpressure, oversized
//! request rejection) need artifacts/ and skip gracefully without it —
//! same convention as integration_runtime.rs.

use recalkv::artifacts::Manifest;
use recalkv::coordinator::batcher::BatchPolicy;
use recalkv::coordinator::{Coordinator, Engine, EngineConfig, GenEvent, GenRequest};
use recalkv::server::protocol::{read_frame, ReadOutcome};
use recalkv::server::{
    Client, ClientFrame, GenOutcome, Server, ServerConfig, ServerFrame, WireError,
    WireErrorKind, WireEvent, WireRequest, WireResult, MAX_FRAME_LEN,
};
use recalkv::util::prop;
use std::path::PathBuf;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// runtime-free protocol properties

/// Random unicode-ish string including newlines, quotes, backslashes and
/// multi-byte chars — everything that could break line framing or JSON
/// escaping.
fn gen_string(ctx: &mut prop::PropCtx, max_len: usize) -> String {
    let n = ctx.usize_in(0, max_len);
    (0..n)
        .map(|_| match ctx.rng.below(8) {
            0 => '\n',
            1 => '"',
            2 => '\\',
            3 => 'é',
            4 => '𝄞',
            5 => '\t',
            6 => char::from_u32(0x20 + ctx.rng.below(0x5f) as u32).unwrap(),
            _ => char::from_u32(0x4e00 + ctx.rng.below(0x100) as u32).unwrap(),
        })
        .collect()
}

fn gen_request(ctx: &mut prop::PropCtx) -> WireRequest {
    let mut req = WireRequest::new(ctx.rng.next_u64(), gen_string(ctx, 48), ctx.usize_in(0, 512));
    req.temperature = (ctx.rng.below(200) as f32) / 100.0;
    req.top_k = ctx.usize_in(0, 64);
    req.seed = ctx.rng.next_u64(); // full u64 range: exercises the string path
    req.priority = ctx.rng.below(11) as i32 - 5;
    req.deadline_ms = if ctx.rng.below(2) == 0 { Some(ctx.rng.next_u64()) } else { None };
    req.stream = ctx.rng.below(2) == 0;
    req
}

#[test]
fn wire_request_roundtrip_property() {
    prop::check("wire_request_roundtrip", 200, |ctx| {
        let req = gen_request(ctx);
        let enc = ClientFrame::Gen(req.clone()).encode();
        if enc.contains('\n') {
            return Err(format!("encoded frame contains a raw newline: {enc}"));
        }
        let dec = ClientFrame::decode(&enc).map_err(|e| format!("decode failed: {e}"))?;
        if dec != ClientFrame::Gen(req) {
            return Err(format!("round trip mismatch: {enc}"));
        }
        Ok(())
    });
}

fn gen_result(ctx: &mut prop::PropCtx, id: u64) -> WireResult {
    use recalkv::coordinator::FinishReason;
    let n = ctx.usize_in(0, 32);
    let reasons = [
        FinishReason::Completed,
        FinishReason::Failed,
        FinishReason::Cancelled,
        FinishReason::DeadlineExceeded,
    ];
    WireResult {
        id,
        tokens: (0..n).map(|_| ctx.rng.below(256) as i32).collect(),
        text: gen_string(ctx, 32),
        forced_logprob: -(ctx.rng.normal().abs() as f64) * 100.0,
        forced_count: ctx.usize_in(0, 32),
        prompt_len: ctx.usize_in(0, 512),
        ttft_ms: ctx.rng.normal().abs() as f64 * 10.0,
        total_ms: ctx.rng.normal().abs() as f64 * 100.0,
        queue_wait_ms: ctx.rng.normal().abs() as f64,
        reason: reasons[ctx.rng.below(4)],
        error: if ctx.rng.below(2) == 0 { Some(gen_string(ctx, 16)) } else { None },
    }
}

#[test]
fn wire_event_roundtrip_property() {
    prop::check("wire_event_roundtrip", 200, |ctx| {
        let id = ctx.rng.next_u64();
        let ev = match ctx.rng.below(7) {
            0 => WireEvent::Queued { id },
            1 => WireEvent::Prefilled {
                id,
                prompt_len: ctx.usize_in(0, 512),
                ttft_ms: ctx.rng.normal().abs() as f64 * 10.0,
            },
            2 => WireEvent::Token {
                id,
                token: ctx.rng.below(256) as i32,
                text_delta: gen_string(ctx, 4),
                logprob: -(ctx.rng.normal().abs() as f64) * 20.0,
            },
            3 => WireEvent::Finished(gen_result(ctx, id)),
            4 => WireEvent::Failed(gen_result(ctx, id)),
            5 => WireEvent::Cancelled(gen_result(ctx, id)),
            _ => WireEvent::DeadlineExceeded(gen_result(ctx, id)),
        };
        let enc = ServerFrame::Event(ev.clone()).encode();
        if enc.contains('\n') {
            return Err(format!("encoded frame contains a raw newline: {enc}"));
        }
        let dec = ServerFrame::decode(&enc).map_err(|e| format!("decode failed: {e}"))?;
        let ServerFrame::Event(got) = dec else {
            return Err(format!("decoded to a non-event frame: {enc}"));
        };
        // logprob fidelity is bitwise, not approximate
        if let (
            WireEvent::Token { logprob: a, .. },
            WireEvent::Token { logprob: b, .. },
        ) = (&ev, &got)
        {
            if a.to_bits() != b.to_bits() {
                return Err(format!("logprob bits changed: {a} -> {b}"));
            }
        }
        if got != ev {
            return Err(format!("round trip mismatch: {enc}"));
        }
        Ok(())
    });
}

/// `ping`/`pong` keepalives round-trip the full u64 sequence range in both
/// directions — the router's health prober matches pongs to probes by seq,
/// so a lossy encoding would read as a permanently stale worker.
#[test]
fn ping_pong_roundtrip_property() {
    prop::check("ping_pong_roundtrip", 200, |ctx| {
        let seq = ctx.rng.next_u64();
        let enc = ClientFrame::Ping { seq }.encode();
        if enc.contains('\n') {
            return Err(format!("encoded ping contains a raw newline: {enc}"));
        }
        match ClientFrame::decode(&enc).map_err(|e| format!("ping decode failed: {e}"))? {
            ClientFrame::Ping { seq: got } if got == seq => {}
            other => return Err(format!("ping round trip mismatch: {enc} -> {other:?}")),
        }
        let enc = ServerFrame::Pong { seq }.encode();
        if enc.contains('\n') {
            return Err(format!("encoded pong contains a raw newline: {enc}"));
        }
        match ServerFrame::decode(&enc).map_err(|e| format!("pong decode failed: {e}"))? {
            ServerFrame::Pong { seq: got } if got == seq => {}
            other => return Err(format!("pong round trip mismatch: {enc} -> {other:?}")),
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// read_frame robustness (runtime-free): truncated, oversized, garbage,
// and interleaved-partial reads, driven through a scripted reader.

/// One scripted delivery step: a chunk of bytes, or a simulated socket
/// read timeout (`WouldBlock`, which `read_frame` reports as `TimedOut`).
enum Step {
    Bytes(Vec<u8>),
    Block,
}

/// Reader that yields its script one step at a time, then EOF. Chunks are
/// further split by the caller's `BufReader` capacity, so byte-at-a-time
/// delivery composes with scripted timeouts.
struct ScriptedReader {
    steps: std::collections::VecDeque<Step>,
}

impl ScriptedReader {
    fn new(steps: Vec<Step>) -> Self {
        ScriptedReader { steps: steps.into() }
    }
}

impl std::io::Read for ScriptedReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            match self.steps.front_mut() {
                None => return Ok(0),
                Some(Step::Block) => {
                    self.steps.pop_front();
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WouldBlock,
                        "scripted timeout",
                    ));
                }
                Some(Step::Bytes(b)) if b.is_empty() => {
                    self.steps.pop_front();
                }
                Some(Step::Bytes(b)) => {
                    let n = b.len().min(buf.len());
                    buf[..n].copy_from_slice(&b[..n]);
                    b.drain(..n);
                    return Ok(n);
                }
            }
        }
    }
}

/// Frames survive arbitrary chunk boundaries, interleaved read timeouts,
/// tiny `BufReader` capacities, and a missing final newline (EOF-terminated
/// last frame) — and every recovered line still decodes.
#[test]
fn read_frame_survives_arbitrary_chunking_and_timeouts() {
    prop::check("read_frame_chunking", 200, |ctx| {
        let n_frames = ctx.usize_in(1, 4);
        let frames: Vec<String> =
            (0..n_frames).map(|_| ClientFrame::Gen(gen_request(ctx)).encode()).collect();
        let mut wire = Vec::new();
        for f in &frames {
            wire.extend_from_slice(f.as_bytes());
            wire.push(b'\n');
        }
        // Half the runs drop the final newline: the last frame must still
        // surface at EOF instead of being silently discarded.
        if ctx.rng.below(2) == 0 {
            wire.pop();
        }
        let mut steps = Vec::new();
        let mut at = 0usize;
        while at < wire.len() {
            if ctx.rng.below(4) == 0 {
                steps.push(Step::Block);
            }
            let n = ctx.usize_in(1, 13).min(wire.len() - at);
            steps.push(Step::Bytes(wire[at..at + n].to_vec()));
            at += n;
        }
        let cap = 1 + ctx.usize_in(0, 7);
        let mut r = std::io::BufReader::with_capacity(cap, ScriptedReader::new(steps));
        let mut acc = Vec::new();
        let mut got: Vec<String> = Vec::new();
        let mut timeouts = 0u32;
        loop {
            match read_frame(&mut r, &mut acc).map_err(|e| format!("io error: {e}"))? {
                ReadOutcome::Frame(line) => got.push(line),
                ReadOutcome::TimedOut => {
                    timeouts += 1;
                    if timeouts > 10_000 {
                        return Err("read loop livelocked on timeouts".into());
                    }
                }
                ReadOutcome::Eof => break,
                ReadOutcome::Oversized { len } => {
                    return Err(format!("spurious oversize report at {len} bytes"));
                }
            }
        }
        if got != frames {
            return Err(format!(
                "frames mangled: {} sent, {} recovered",
                frames.len(),
                got.len()
            ));
        }
        for line in &got {
            ClientFrame::decode(line).map_err(|e| format!("recovered frame undecodable: {e}"))?;
        }
        Ok(())
    });
}

/// Non-UTF-8 garbage on the wire surfaces as a typed `InvalidData` io
/// error from the framing layer — never a panic, never a silent drop.
#[test]
fn read_frame_garbage_bytes_report_invalid_data() {
    let wire: Vec<u8> = vec![b'{', 0xff, 0xfe, 0x80, b'}', b'\n'];
    let mut r = std::io::BufReader::new(&wire[..]);
    let mut acc = Vec::new();
    match read_frame(&mut r, &mut acc) {
        Err(e) => assert_eq!(e.kind(), std::io::ErrorKind::InvalidData),
        Ok(out) => panic!("garbage line accepted: {out:?}"),
    }
}

/// A newline-free flood larger than the cap is reported `Oversized` even
/// when it never terminates — the reader must not buffer unboundedly
/// waiting for a newline that never comes.
#[test]
fn read_frame_unterminated_flood_reports_oversized() {
    let wire = vec![b'z'; MAX_FRAME_LEN + 2];
    let mut r = std::io::BufReader::new(&wire[..]);
    let mut acc = Vec::new();
    match read_frame(&mut r, &mut acc) {
        Ok(ReadOutcome::Oversized { len }) => assert!(len > MAX_FRAME_LEN),
        other => panic!("flood not reported oversized: {other:?}"),
    }
    assert!(acc.is_empty(), "oversized line must not linger in the accumulator");
}

/// A frame truncated by EOF (no trailing newline) is still delivered,
/// followed by a clean `Eof`.
#[test]
fn read_frame_truncated_final_frame_then_eof() {
    let wire = b"{\"op\":\"metrics\"}".to_vec();
    let mut r = std::io::BufReader::new(&wire[..]);
    let mut acc = Vec::new();
    match read_frame(&mut r, &mut acc) {
        Ok(ReadOutcome::Frame(line)) => assert_eq!(line, "{\"op\":\"metrics\"}"),
        other => panic!("truncated final frame lost: {other:?}"),
    }
    assert!(matches!(read_frame(&mut r, &mut acc), Ok(ReadOutcome::Eof)));
}

// ---------------------------------------------------------------------------
// end-to-end server tests (need artifacts/)

fn manifest_dir() -> Option<PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] artifacts/ not built");
        return None;
    }
    Some(dir)
}

/// Spawn a coordinator + wire server on an ephemeral loopback port.
/// Returns the client-facing address, the coordinator (shut it down last),
/// and the server thread's join handle (joins after `shutdown_server`).
fn spawn_server(
    dir: PathBuf,
    ecfg: EngineConfig,
    scfg: ServerConfig,
) -> (String, Coordinator, std::thread::JoinHandle<anyhow::Result<()>>) {
    let coord = Coordinator::spawn(move || {
        let man = Manifest::load(&dir)?;
        let rt = recalkv::runtime::Runtime::cpu()?;
        let model = man.model("tiny-mha")?;
        Engine::new(&rt, model, model.variant("recal@50")?, ecfg)
    });
    let server = Server::bind("127.0.0.1:0", coord.handle(), scfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let worker = std::thread::spawn(move || server.run());
    (addr, coord, worker)
}

fn stop_server(addr: &str, coord: Coordinator, worker: std::thread::JoinHandle<anyhow::Result<()>>) {
    let mut c = Client::connect(addr).expect("connect for shutdown");
    c.shutdown_server().expect("shutdown handshake");
    worker.join().expect("server thread panicked").expect("server run failed");
    coord.shutdown().expect("coordinator shutdown");
}

#[test]
fn wire_generation_matches_in_process_bitwise() {
    let Some(dir) = manifest_dir() else { return };
    let prompt_text = "bob has a red key . the dog barks . ";
    let max_new = 16usize;

    // in-process reference: greedy generation, token logprobs from the
    // event stream
    let man = Manifest::load(&dir).unwrap();
    let rt = recalkv::runtime::Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    let prompt = recalkv::coordinator::tokenizer::encode(prompt_text);
    engine.submit(GenRequest::new(1, prompt, max_new)).unwrap();
    let mut ref_tokens: Vec<i32> = Vec::new();
    let mut ref_logprobs: Vec<f64> = Vec::new();
    let mut ref_deltas = String::new();
    let mut ref_result = None;
    while ref_result.is_none() {
        engine.step().unwrap();
        for ev in engine.poll_events() {
            match ev {
                GenEvent::Token { token, logprob, text_delta, .. } => {
                    ref_tokens.push(token);
                    ref_logprobs.push(logprob);
                    ref_deltas.push_str(&text_delta);
                }
                ev if ev.is_terminal() => ref_result = ev.into_result(),
                _ => {}
            }
        }
    }
    let ref_result = ref_result.unwrap();
    assert_eq!(ref_tokens, ref_result.tokens);

    // the same request over the TCP wire
    let (addr, coord, worker) =
        spawn_server(dir, EngineConfig::default(), ServerConfig::default());
    let mut client = Client::connect(&addr).unwrap();
    let outcome = client.generate(&WireRequest::new(1, prompt_text, max_new)).unwrap();
    let GenOutcome::Done { events } = outcome else { panic!("wire request rejected") };
    let mut wire_tokens: Vec<i32> = Vec::new();
    let mut wire_logprobs: Vec<f64> = Vec::new();
    let mut wire_deltas = String::new();
    let mut wire_result = None;
    for (ev, _) in &events {
        match ev {
            WireEvent::Token { token, logprob, text_delta, .. } => {
                wire_tokens.push(*token);
                wire_logprobs.push(*logprob);
                wire_deltas.push_str(text_delta);
            }
            WireEvent::Finished(r) => wire_result = Some(r.clone()),
            other => assert!(!other.is_terminal(), "wire generation ended {other:?}"),
        }
    }
    let wire_result = wire_result.expect("no terminal wire event");

    assert_eq!(wire_tokens, ref_tokens, "wire tokens diverge from in-process");
    assert_eq!(wire_result.tokens, ref_result.tokens);
    assert_eq!(wire_result.text, ref_result.text, "terminal text diverges");
    assert_eq!(wire_deltas, ref_deltas, "streamed deltas diverge");
    assert_eq!(wire_logprobs.len(), ref_logprobs.len());
    for (i, (w, r)) in wire_logprobs.iter().zip(&ref_logprobs).enumerate() {
        assert_eq!(
            w.to_bits(),
            r.to_bits(),
            "logprob {i} not bitwise identical over the wire: {w} vs {r}"
        );
    }
    stop_server(&addr, coord, worker);
}

#[test]
fn disconnect_cancels_and_reclaims_pages() {
    let Some(dir) = manifest_dir() else { return };
    let (addr, coord, worker) =
        spawn_server(dir, EngineConfig::default(), ServerConfig::default());

    // a long-running streamed request we will abandon mid-flight
    {
        let mut victim = Client::connect(&addr).unwrap();
        victim
            .send(&ClientFrame::Gen(WireRequest::new(
                1,
                "the dog barks . the cat sleeps . ",
                400,
            )))
            .unwrap();
        let mut tokens_seen = 0;
        while tokens_seen < 2 {
            match victim.recv().unwrap() {
                ServerFrame::Event(WireEvent::Token { .. }) => tokens_seen += 1,
                ServerFrame::Event(ev) => {
                    assert!(!ev.is_terminal(), "request ended before disconnect: {ev:?}")
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
        // dropping the client closes the socket: the server must cancel
    }

    // observe the reclamation through a second connection's metrics frames
    let mut observer = Client::connect(&addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = observer.metrics().unwrap();
        let cancelled = stats
            .req("metrics")
            .req("requests_cancelled")
            .as_f64()
            .unwrap_or(0.0) as u64;
        if cancelled >= 1 {
            let cache = stats.req("cache");
            assert_eq!(
                cache.req("blocks_in_use").as_usize(),
                Some(0),
                "disconnect leaked cache pages: {stats}"
            );
            assert_eq!(
                cache.req("live_seqs").as_usize(),
                Some(0),
                "disconnect leaked sequences: {stats}"
            );
            assert_eq!(cache.req("total_tokens").as_usize(), Some(0));
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnect never cancelled the request: {stats}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    stop_server(&addr, coord, worker);
}

#[test]
fn nth_concurrent_wire_request_gets_queue_full() {
    let Some(dir) = manifest_dir() else { return };
    // per-connection cap 2: the 3rd concurrent gen on one socket must
    // bounce with the retryable queue_full kind
    let (addr, coord, worker) = spawn_server(
        dir,
        EngineConfig::default(),
        ServerConfig { max_inflight_per_conn: 2, max_inflight_global: 64, ..Default::default() },
    );
    let mut client = Client::connect(&addr).unwrap();
    for id in 1..=3u64 {
        client
            .send(&ClientFrame::Gen(WireRequest::new(id, "the dog barks . ", 32)))
            .unwrap();
    }
    let mut rejection: Option<WireError> = None;
    let mut terminals = 0;
    while terminals < 2 || rejection.is_none() {
        match client.recv().unwrap() {
            ServerFrame::Error(e) => {
                assert_eq!(e.id, Some(3), "only the 3rd request may be rejected: {e:?}");
                assert_eq!(e.kind, WireErrorKind::QueueFull { capacity: 2 });
                assert!(e.kind.retryable(), "queue_full must be retryable");
                rejection = Some(e);
            }
            ServerFrame::Event(ev) => {
                assert_ne!(ev.id(), 3, "rejected request must produce no events");
                if ev.is_terminal() {
                    let r = ev.result().unwrap();
                    assert!(r.error.is_none(), "in-cap request failed: {:?}", r.error);
                    terminals += 1;
                }
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    // after the first two drained, a retry of id 3 is admitted
    match client.generate(&WireRequest::new(3, "the dog barks . ", 4)).unwrap() {
        GenOutcome::Done { events } => {
            let (last, _) = events.last().unwrap();
            assert!(matches!(last, WireEvent::Finished(_)), "retry must finish: {last:?}");
        }
        GenOutcome::Rejected(e) => panic!("retry after drain still rejected: {e:?}"),
    }
    stop_server(&addr, coord, worker);
}

#[test]
fn oversized_request_rejected_as_too_large() {
    let Some(dir) = manifest_dir() else { return };
    let (addr, coord, worker) = spawn_server(
        dir,
        EngineConfig { max_cache_tokens: 16, ..Default::default() },
        ServerConfig::default(),
    );
    let mut client = Client::connect(&addr).unwrap();
    // 12 prompt bytes + 8 new = 20 > 16: typed, non-retryable rejection
    match client.generate(&WireRequest::new(1, "twelve bytes", 8)).unwrap() {
        GenOutcome::Rejected(e) => {
            assert_eq!(e.kind, WireErrorKind::TooLarge { need: 20, budget: 16 });
            assert!(!e.kind.retryable(), "too_large must not be retryable");
            assert_eq!(e.id, Some(1));
        }
        GenOutcome::Done { .. } => panic!("oversized request was admitted"),
    }
    // within budget (12 + 4 = 16) passes on the same connection
    match client.generate(&WireRequest::new(1, "twelve bytes", 4)).unwrap() {
        GenOutcome::Done { events } => {
            let (last, _) = events.last().unwrap();
            assert!(matches!(last, WireEvent::Finished(_)), "in-budget must finish");
        }
        GenOutcome::Rejected(e) => panic!("in-budget request rejected: {e:?}"),
    }
    stop_server(&addr, coord, worker);
}

#[test]
fn version_mismatch_is_rejected_at_handshake() {
    let Some(dir) = manifest_dir() else { return };
    let (addr, coord, worker) =
        spawn_server(dir, EngineConfig::default(), ServerConfig::default());
    // raw socket: speak a future protocol version
    {
        use std::io::{BufRead, BufReader, Write};
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(b"{\"op\":\"hello\",\"version\":999}\n").unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        let ServerFrame::Error(e) = ServerFrame::decode(&line).unwrap() else {
            panic!("expected error frame, got {line}");
        };
        assert_eq!(e.kind, WireErrorKind::UnsupportedVersion { server: 1, client: 999 });
    }
    // a well-versioned client still connects fine afterwards
    Client::connect(&addr).unwrap();
    stop_server(&addr, coord, worker);
}

// keep clippy quiet about the unused import when artifacts are absent:
// BatchPolicy is exercised here so wire serving covers non-default policies
#[test]
fn wire_serves_under_full_batching_policy() {
    let Some(dir) = manifest_dir() else { return };
    let (addr, coord, worker) = spawn_server(
        dir,
        EngineConfig { policy: BatchPolicy::Full, ..Default::default() },
        ServerConfig::default(),
    );
    let mut client = Client::connect(&addr).unwrap();
    match client.generate(&WireRequest::new(7, "the dog barks . ", 6)).unwrap() {
        GenOutcome::Done { events } => {
            let (last, _) = events.last().unwrap();
            let WireEvent::Finished(r) = last else { panic!("did not finish: {last:?}") };
            assert_eq!(r.tokens.len(), 6);
        }
        GenOutcome::Rejected(e) => panic!("rejected under full policy: {e:?}"),
    }
    stop_server(&addr, coord, worker);
}
