//! Bit-identity guarantees of the parallel offline pipeline.
//!
//! The work pool (`util::pool`) and the tiled GEMM (`linalg::gemm`) promise
//! that thread count and kernel choice never change output bits. These
//! tests pin that promise: the tiled kernel against the seed scalar loop
//! over random shapes (including k = 0 and 1×1), and the parallel
//! pipeline / CKA / grouped-SVD paths against forced single-thread runs
//! (`PALLAS_THREADS=1` equivalent via `pool::set_threads(1)`), in f32 and
//! quantized cache configurations.

use recalkv::compress::{cka, compress_layer, compress_layers, svdc, LayerInputs, MethodCfg};
use recalkv::kvcache::{CacheConfig, KvCache};
use recalkv::linalg::gemm::gemm_tiled;
use recalkv::linalg::Matrix;
use recalkv::prop_assert;
use recalkv::quant::QuantKind;
use recalkv::util::pool;
use recalkv::util::prop::check;
use recalkv::util::rng::Rng;
use std::sync::Mutex;

/// Serializes tests that touch the process-global pool override. (Thread
/// count never changes results — that is what these tests prove — but the
/// forced single-thread halves of the comparisons must not race another
/// test's override.)
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn gemm_tiled_matches_naive_over_random_shapes() {
    check("gemm_equivalence", 30, |ctx| {
        let m = ctx.usize_in(1, 40);
        let k = ctx.usize_in(1, 40);
        let n = ctx.usize_in(1, 40);
        let mut a = Matrix::from_vec(m, k, ctx.f32_vec(m * k, 1.0));
        // plant exact zeros so the kernel's zero-skip path is exercised
        for v in a.data.iter_mut() {
            if ctx.rng.below(5) == 0 {
                *v = 0.0;
            }
        }
        let b = Matrix::from_vec(k, n, ctx.f32_vec(k * n, 1.0));
        let naive = a.matmul_naive(&b);
        let tiled = gemm_tiled(&a, &b);
        prop_assert!(bits_equal(&naive, &tiled), "{m}x{k}x{n}: tiled != naive");
        prop_assert!(bits_equal(&naive, &a.matmul(&b)), "{m}x{k}x{n}: dispatch != naive");
        Ok(())
    });
}

#[test]
fn gemm_edge_shapes_match_naive() {
    // k = 0 (empty inner dimension) and 1×1
    let a = Matrix::zeros(4, 0);
    let b = Matrix::zeros(0, 6);
    assert!(bits_equal(&a.matmul_naive(&b), &gemm_tiled(&a, &b)));
    let one = Matrix::from_vec(1, 1, vec![3.25]);
    let two = Matrix::from_vec(1, 1, vec![-0.5]);
    assert!(bits_equal(&one.matmul_naive(&two), &gemm_tiled(&one, &two)));
}

#[test]
fn gemm_multithreaded_matches_naive() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut rng = Rng::new(23);
    // big enough to cross the kernel's parallel threshold
    let a = Matrix::from_fn(130, 150, |_, _| rng.normal());
    let b = Matrix::from_fn(150, 140, |_, _| rng.normal());
    let naive = a.matmul_naive(&b);
    for threads in [1usize, 4] {
        pool::set_threads(threads);
        assert!(bits_equal(&naive, &a.matmul(&b)), "threads={threads}");
    }
    pool::set_threads(0);
}

fn layer_fixture(seed: u64) -> (Matrix, Matrix, Matrix, Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let d = 16;
    let h = 4;
    let dh = 4;
    let wq = Matrix::from_fn(d, h * dh, |_, _| rng.normal() * 0.1);
    let wk = Matrix::from_fn(d, h * dh, |_, _| rng.normal() * 0.1);
    let wv = Matrix::from_fn(d, h * dh, |_, _| rng.normal() * 0.1);
    let wo = Matrix::from_fn(h * dh, d, |_, _| rng.normal() * 0.1);
    let x = Matrix::from_fn(64, d, |_, _| rng.normal());
    let m = x.gram();
    (wq, wk, wv, wo, x, m)
}

#[test]
fn head_similarity_parallel_matches_serial_pair_loop() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let (_, wk, _, _, x, _) = layer_fixture(101);
    // serial reference: the seed's literal double loop over cka()
    pool::set_threads(1);
    let dh = wk.cols / 4;
    let heads: Vec<Matrix> =
        (0..4).map(|i| x.matmul(&wk.cols_slice(i * dh, (i + 1) * dh))).collect();
    let mut want = Matrix::eye(4);
    for i in 0..4 {
        for j in (i + 1)..4 {
            let v = cka::cka(&heads[i], &heads[j]) as f32;
            want[(i, j)] = v;
            want[(j, i)] = v;
        }
    }
    for threads in [1usize, 4] {
        pool::set_threads(threads);
        let got = cka::head_similarity(&x, &wk, 4);
        assert!(bits_equal(&want, &got), "threads={threads}: similarity diverged");
    }
    pool::set_threads(0);
}

#[test]
fn grouped_svd_parallel_matches_single_thread() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let (_, wk, _, _, _, m) = layer_fixture(103);
    let perm: Vec<usize> = vec![2, 0, 3, 1];
    for whiten in [None, Some(&m)] {
        pool::set_threads(1);
        let (l1, r1) = svdc::grouped_svd(&wk, &perm, 2, 3, 4, whiten, 1e-4).unwrap();
        pool::set_threads(4);
        let (l4, r4) = svdc::grouped_svd(&wk, &perm, 2, 3, 4, whiten, 1e-4).unwrap();
        assert!(bits_equal(&l1, &l4), "whiten={}: L diverged", whiten.is_some());
        assert_eq!(r1.len(), r4.len());
        for (a, b) in r1.iter().zip(&r4) {
            assert!(bits_equal(a, b), "whiten={}: R diverged", whiten.is_some());
        }
    }
    pool::set_threads(0);
}

/// Full per-layer pipeline: parallel run bit-identical to the forced
/// single-thread run, for the f32 ablations and the grouped (palu) path,
/// and the staged cache image built from the factors is bit-identical in
/// both f32 and int4 cache modes.
#[test]
fn pipeline_parallel_matches_single_thread_f32_and_quantized() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let (wq, wk, wv, wo, x, m) = layer_fixture(107);
    let inp = || LayerInputs {
        w_q: &wq, w_k: &wk, w_v: &wv, w_o: &wo, m: &m, x_sample: &x,
        n_heads: 4, n_kv_heads: 4, d_head: 4, group_size: 2,
        key_rank: 6, value_rank: 8,
    };
    for method in ["recal", "palu"] {
        let cfg = MethodCfg::from_name(method).unwrap();
        pool::set_threads(1);
        let serial = compress_layer(&inp(), cfg).unwrap();
        pool::set_threads(4);
        let inputs = vec![inp(), inp()];
        let par = compress_layers(&inputs, cfg).unwrap();
        for (li, p) in par.iter().enumerate() {
            assert_eq!(serial.kv_perm, p.kv_perm, "{method} L{li}: perm diverged");
            for (name, a, b) in [
                ("wq", &serial.wq_reordered, &p.wq_reordered),
                ("l_k", &serial.l_k, &p.l_k),
                ("l_v", &serial.l_v, &p.l_v),
                ("wo_fused", &serial.wo_fused, &p.wo_fused),
                ("cka", &serial.cka, &p.cka),
            ] {
                assert!(bits_equal(a, b), "{method} L{li}: {name} diverged");
            }
            for (a, b) in serial.r_k.iter().zip(&p.r_k) {
                assert!(bits_equal(a, b), "{method} L{li}: r_k diverged");
            }
            assert_eq!(serial.key_error.to_bits(), p.key_error.to_bits(), "{method} L{li}");
            assert_eq!(
                serial.value_error_post.to_bits(),
                p.value_error_post.to_bits(),
                "{method} L{li}"
            );
        }
        // Stage the two runs' latents through the quantized cache: equal
        // factors must produce bit-identical staged images in every mode.
        let lat = |cl: &recalkv::compress::CompressedLayer| {
            (x.matmul(&cl.l_k), x.matmul(&cl.l_v))
        };
        let (k1, v1) = lat(&serial);
        let (k2, v2) = lat(&par[0]);
        for quant in [QuantKind::F32, QuantKind::Int4] {
            let mut staged = Vec::new();
            for (klat, vlat) in [(&k1, &v1), (&k2, &v2)] {
                let mut c = KvCache::new(CacheConfig {
                    n_layers: 1,
                    widths: vec![(klat.cols, vlat.cols)],
                    cache_len: 16,
                    tokens_per_block: 4,
                    capacity_tokens: 16,
                    quant,
                    signs_seed: 11,
                });
                let s = c.new_seq();
                for t in 0..8 {
                    c.append(s, &[(klat.row(t), vlat.row(t))]).unwrap();
                }
                let mut out = vec![0.0f32; 8 * klat.cols];
                c.stage_rows(s, 0, 0, 0, 8, &mut out).unwrap();
                let mut vout = vec![0.0f32; 8 * vlat.cols];
                c.stage_rows(s, 0, 1, 0, 8, &mut vout).unwrap();
                out.extend(vout);
                staged.push(out);
            }
            assert!(
                staged[0].iter().zip(&staged[1]).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{method} {quant:?}: staged images diverged"
            );
        }
    }
    pool::set_threads(0);
}
