//! Bit-identity guarantees of the parallel offline pipeline.
//!
//! The work pool (`util::pool`), the tiled GEMM (`linalg::gemm`) and the
//! SIMD micro-kernels (`linalg::simd`, dispatched by `util::simd`) promise
//! that thread count and kernel choice never change output bits. These
//! tests pin that promise: the tiled kernel against the seed scalar loop
//! over random shapes (including k = 0 and 1×1), SIMD dispatch against the
//! forced-scalar twins over GEMM / FWHT / quantization (tile tails, signed
//! zeros, non-finite values included), and the parallel pipeline / CKA /
//! grouped-SVD paths against forced single-thread runs
//! (`PALLAS_THREADS=1` equivalent via `pool::set_threads(1)`), in f32 and
//! quantized cache configurations.

use recalkv::compress::{
    cka, compress_layer, compress_layer_ranks, compress_layers, svdc, LayerInputs, MethodCfg,
};
use recalkv::kvcache::{CacheConfig, KvCache};
use recalkv::linalg::gemm::gemm_tiled;
use recalkv::linalg::hadamard::{forward, inverse, signs_from_seed};
use recalkv::linalg::Matrix;
use recalkv::prop_assert;
use recalkv::quant::{dequantize, quantize, QuantKind};
use recalkv::util::pool;
use recalkv::util::prop::check;
use recalkv::util::rng::Rng;
use recalkv::util::simd;
use std::sync::Mutex;

/// Serializes tests that touch the process-global pool or SIMD overrides.
/// (Neither override changes results — that is what these tests prove —
/// but the forced halves of the comparisons must not race another test's
/// override.)
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn gemm_tiled_matches_naive_over_random_shapes() {
    check("gemm_equivalence", 30, |ctx| {
        let m = ctx.usize_in(1, 40);
        let k = ctx.usize_in(1, 40);
        let n = ctx.usize_in(1, 40);
        let mut a = Matrix::from_vec(m, k, ctx.f32_vec(m * k, 1.0));
        // plant exact zeros so the kernel's zero-skip path is exercised
        for v in a.data.iter_mut() {
            if ctx.rng.below(5) == 0 {
                *v = 0.0;
            }
        }
        let b = Matrix::from_vec(k, n, ctx.f32_vec(k * n, 1.0));
        let naive = a.matmul_naive(&b);
        let tiled = gemm_tiled(&a, &b);
        prop_assert!(bits_equal(&naive, &tiled), "{m}x{k}x{n}: tiled != naive");
        prop_assert!(bits_equal(&naive, &a.matmul(&b)), "{m}x{k}x{n}: dispatch != naive");
        Ok(())
    });
}

#[test]
fn gemm_edge_shapes_match_naive() {
    // k = 0 (empty inner dimension) and 1×1
    let a = Matrix::zeros(4, 0);
    let b = Matrix::zeros(0, 6);
    assert!(bits_equal(&a.matmul_naive(&b), &gemm_tiled(&a, &b)));
    let one = Matrix::from_vec(1, 1, vec![3.25]);
    let two = Matrix::from_vec(1, 1, vec![-0.5]);
    assert!(bits_equal(&one.matmul_naive(&two), &gemm_tiled(&one, &two)));
}

#[test]
fn gemm_multithreaded_matches_naive() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut rng = Rng::new(23);
    // big enough to cross the kernel's parallel threshold
    let a = Matrix::from_fn(130, 150, |_, _| rng.normal());
    let b = Matrix::from_fn(150, 140, |_, _| rng.normal());
    let naive = a.matmul_naive(&b);
    for threads in [1usize, 4] {
        pool::set_threads(threads);
        assert!(bits_equal(&naive, &a.matmul(&b)), "threads={threads}");
    }
    pool::set_threads(0);
}

fn layer_fixture(seed: u64) -> (Matrix, Matrix, Matrix, Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let d = 16;
    let h = 4;
    let dh = 4;
    let wq = Matrix::from_fn(d, h * dh, |_, _| rng.normal() * 0.1);
    let wk = Matrix::from_fn(d, h * dh, |_, _| rng.normal() * 0.1);
    let wv = Matrix::from_fn(d, h * dh, |_, _| rng.normal() * 0.1);
    let wo = Matrix::from_fn(h * dh, d, |_, _| rng.normal() * 0.1);
    let x = Matrix::from_fn(64, d, |_, _| rng.normal());
    let m = x.gram();
    (wq, wk, wv, wo, x, m)
}

#[test]
fn head_similarity_parallel_matches_serial_pair_loop() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let (_, wk, _, _, x, _) = layer_fixture(101);
    // serial reference: the seed's literal double loop over cka()
    pool::set_threads(1);
    let dh = wk.cols / 4;
    let heads: Vec<Matrix> =
        (0..4).map(|i| x.matmul(&wk.cols_slice(i * dh, (i + 1) * dh))).collect();
    let mut want = Matrix::eye(4);
    for i in 0..4 {
        for j in (i + 1)..4 {
            let v = cka::cka(&heads[i], &heads[j]) as f32;
            want[(i, j)] = v;
            want[(j, i)] = v;
        }
    }
    for threads in [1usize, 4] {
        pool::set_threads(threads);
        let got = cka::head_similarity(&x, &wk, 4);
        assert!(bits_equal(&want, &got), "threads={threads}: similarity diverged");
    }
    pool::set_threads(0);
}

#[test]
fn grouped_svd_parallel_matches_single_thread() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let (_, wk, _, _, _, m) = layer_fixture(103);
    let perm: Vec<usize> = vec![2, 0, 3, 1];
    for whiten in [None, Some(&m)] {
        pool::set_threads(1);
        let (l1, r1) = svdc::grouped_svd(&wk, &perm, 2, 3, 4, whiten, 1e-4).unwrap();
        pool::set_threads(4);
        let (l4, r4) = svdc::grouped_svd(&wk, &perm, 2, 3, 4, whiten, 1e-4).unwrap();
        assert!(bits_equal(&l1, &l4), "whiten={}: L diverged", whiten.is_some());
        assert_eq!(r1.len(), r4.len());
        for (a, b) in r1.iter().zip(&r4) {
            assert!(bits_equal(a, b), "whiten={}: R diverged", whiten.is_some());
        }
    }
    pool::set_threads(0);
}

/// Full per-layer pipeline: parallel run bit-identical to the forced
/// single-thread run, for the f32 ablations and the grouped (palu) path,
/// and the staged cache image built from the factors is bit-identical in
/// both f32 and int4 cache modes.
#[test]
fn pipeline_parallel_matches_single_thread_f32_and_quantized() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let (wq, wk, wv, wo, x, m) = layer_fixture(107);
    let inp = || LayerInputs {
        w_q: &wq, w_k: &wk, w_v: &wv, w_o: &wo, m: &m, x_sample: &x,
        n_heads: 4, n_kv_heads: 4, d_head: 4, group_size: 2,
        key_rank: 6, value_rank: 8,
    };
    for method in ["recal", "palu"] {
        let cfg = MethodCfg::from_name(method).unwrap();
        pool::set_threads(1);
        let serial = compress_layer(&inp(), cfg).unwrap();
        pool::set_threads(4);
        let inputs = vec![inp(), inp()];
        let par = compress_layers(&inputs, cfg).unwrap();
        for (li, p) in par.iter().enumerate() {
            assert_eq!(serial.kv_perm, p.kv_perm, "{method} L{li}: perm diverged");
            for (name, a, b) in [
                ("wq", &*serial.wq_reordered, &*p.wq_reordered),
                ("l_k", &serial.l_k, &p.l_k),
                ("l_v", &serial.l_v, &p.l_v),
                ("wo_fused", &serial.wo_fused, &p.wo_fused),
                ("cka", &*serial.cka, &*p.cka),
            ] {
                assert!(bits_equal(a, b), "{method} L{li}: {name} diverged");
            }
            for (a, b) in serial.r_k.iter().zip(&p.r_k) {
                assert!(bits_equal(a, b), "{method} L{li}: r_k diverged");
            }
            assert_eq!(serial.key_error.to_bits(), p.key_error.to_bits(), "{method} L{li}");
            assert_eq!(
                serial.value_error_post.to_bits(),
                p.value_error_post.to_bits(),
                "{method} L{li}"
            );
        }
        // Stage the two runs' latents through the quantized cache: equal
        // factors must produce bit-identical staged images in every mode.
        let lat = |cl: &recalkv::compress::CompressedLayer| {
            (x.matmul(&cl.l_k), x.matmul(&cl.l_v))
        };
        let (k1, v1) = lat(&serial);
        let (k2, v2) = lat(&par[0]);
        for quant in [QuantKind::F32, QuantKind::Int4] {
            let mut staged = Vec::new();
            for (klat, vlat) in [(&k1, &v1), (&k2, &v2)] {
                let mut c = KvCache::new(CacheConfig {
                    n_layers: 1,
                    widths: vec![(klat.cols, vlat.cols)],
                    cache_len: 16,
                    tokens_per_block: 4,
                    capacity_tokens: 16,
                    quant,
                    signs_seed: 11,
                });
                let s = c.new_seq();
                for t in 0..8 {
                    c.append(s, &[(klat.row(t), vlat.row(t))]).unwrap();
                }
                let mut out = vec![0.0f32; 8 * klat.cols];
                c.stage_rows(s, 0, 0, 0, 8, &mut out).unwrap();
                let mut vout = vec![0.0f32; 8 * vlat.cols];
                c.stage_rows(s, 0, 1, 0, 8, &mut vout).unwrap();
                out.extend(vout);
                staged.push(out);
            }
            assert!(
                staged[0].iter().zip(&staged[1]).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{method} {quant:?}: staged images diverged"
            );
        }
    }
    pool::set_threads(0);
}

// ----------------------------- SIMD vs scalar ----------------------------

fn bits_equal_slice(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// GEMM over random shapes (tile tails included), with planted signed
/// zeros in A and non-finite values in B: the SIMD dispatch, the
/// forced-scalar twin and the seed naive loop must agree bit for bit —
/// the zero-skip tests the broadcast A scalar and NaN/inf propagate
/// per lane, so even the pathological inputs cannot diverge.
#[test]
fn simd_gemm_matches_scalar_and_naive_bitwise() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    check("simd_gemm_equivalence", 30, |ctx| {
        let m = ctx.usize_in(1, 40);
        let k = ctx.usize_in(1, 40);
        let n = ctx.usize_in(1, 40);
        let mut a = Matrix::from_vec(m, k, ctx.f32_vec(m * k, 1.0));
        for v in a.data.iter_mut() {
            match ctx.rng.below(8) {
                0 => *v = 0.0,
                1 => *v = -0.0,
                _ => {}
            }
        }
        let mut b = Matrix::from_vec(k, n, ctx.f32_vec(k * n, 1.0));
        for v in b.data.iter_mut() {
            match ctx.rng.below(24) {
                0 => *v = f32::NAN,
                1 => *v = f32::INFINITY,
                2 => *v = f32::NEG_INFINITY,
                _ => {}
            }
        }
        let naive = a.matmul_naive(&b);
        simd::set_force_scalar(true);
        let scalar = gemm_tiled(&a, &b);
        simd::set_force_scalar(false);
        let vector = gemm_tiled(&a, &b);
        prop_assert!(bits_equal_slice(&naive.data, &scalar.data), "{m}x{k}x{n}: scalar != naive");
        prop_assert!(bits_equal_slice(&scalar.data, &vector.data), "{m}x{k}x{n}: simd != scalar");
        Ok(())
    });
}

/// FWHT forward/inverse and the full quantize→dequantize round (which runs
/// the Hadamard, the int4 lane decode and the scale multiply through the
/// dispatch layer): SIMD on vs forced scalar, bit for bit, over block
/// sizes with and without vector-width tails.
#[test]
fn simd_fwht_and_dequant_match_scalar_bitwise() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    check("simd_fwht_dequant_equivalence", 25, |ctx| {
        let n = 4 * ctx.usize_in(1, 32); // multiples of 4, FWHT blocks 4..64
        let signs = signs_from_seed(ctx.seed, n);
        let rows = ctx.f32_vec(3 * n, 1.5);

        simd::set_force_scalar(true);
        let mut fwd_s = rows.clone();
        forward(&mut fwd_s, &signs);
        let mut inv_s = fwd_s.clone();
        inverse(&mut inv_s, &signs);
        simd::set_force_scalar(false);
        let mut fwd_v = rows.clone();
        forward(&mut fwd_v, &signs);
        let mut inv_v = fwd_v.clone();
        inverse(&mut inv_v, &signs);
        prop_assert!(bits_equal_slice(&fwd_s, &fwd_v), "n={n}: forward diverged");
        prop_assert!(bits_equal_slice(&inv_s, &inv_v), "n={n}: inverse diverged");

        for kind in [QuantKind::Int4, QuantKind::Int3] {
            let x = &rows[..n];
            simd::set_force_scalar(true);
            let q_s = quantize(x, &signs, kind);
            let mut d_s = vec![0.0f32; n];
            dequantize(&q_s, &signs, &mut d_s);
            simd::set_force_scalar(false);
            let q_v = quantize(x, &signs, kind);
            let mut d_v = vec![0.0f32; n];
            dequantize(&q_v, &signs, &mut d_v);
            prop_assert!(
                q_s.packed == q_v.packed && q_s.scale.to_bits() == q_v.scale.to_bits(),
                "{kind:?} n={n}: quantized codes diverged"
            );
            prop_assert!(bits_equal_slice(&d_s, &d_v), "{kind:?} n={n}: dequant diverged");
        }
        Ok(())
    });
}

/// The dispatch policy itself: every documented `PALLAS_SIMD=off` spelling
/// routes to the scalar tier regardless of hardware, anything else falls
/// through to detection, and the runtime override used by benches and the
/// tests above forces scalar mid-process.
#[test]
fn pallas_simd_off_routes_to_scalar_twins() {
    use recalkv::util::simd::{hardware_tier, resolve, set_force_scalar, tier, Tier};
    for v in ["off", "0", "scalar", "none", "OFF"] {
        for hw in [Tier::Scalar, Tier::Avx2, Tier::Neon] {
            assert_eq!(resolve(Some(v), hw), Tier::Scalar, "PALLAS_SIMD={v} on {hw:?}");
        }
    }
    for v in [None, Some("auto"), Some("on"), Some("")] {
        assert_eq!(resolve(v, hardware_tier()), hardware_tier(), "PALLAS_SIMD={v:?}");
    }
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    set_force_scalar(true);
    assert_eq!(tier(), Tier::Scalar, "runtime override ignored");
    set_force_scalar(false);
    assert_eq!(tier(), resolve(std::env::var("PALLAS_SIMD").ok().as_deref(), hardware_tier()));
}

/// `compress_layer_ranks` (the sweep path) must reproduce standalone
/// `compress_layer` runs bit for bit at every rank in the sweep — the
/// shared CKA/SVD pass never sees the rank.
#[test]
fn rank_sweep_matches_standalone_runs_bitwise() {
    let _g = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    pool::set_threads(2);
    let (wq, wk, wv, wo, x, m) = layer_fixture(109);
    let mk_inp = |key_rank: usize, value_rank: usize| LayerInputs {
        w_q: &wq, w_k: &wk, w_v: &wv, w_o: &wo, m: &m, x_sample: &x,
        n_heads: 4, n_kv_heads: 4, d_head: 4, group_size: 2,
        key_rank, value_rank,
    };
    let ranks = [(2usize, 4usize), (4, 8), (6, 12)];
    for method in ["recal", "palu"] {
        let cfg = MethodCfg::from_name(method).unwrap();
        let swept = compress_layer_ranks(&mk_inp(0, 0), cfg, &ranks).unwrap();
        assert_eq!(swept.len(), ranks.len());
        // the rank-independent matrices must be *shared* across entries,
        // not duplicated per rank (one allocation per layer sweep)
        for s in &swept[1..] {
            assert!(std::sync::Arc::ptr_eq(&swept[0].wq_reordered, &s.wq_reordered),
                    "{method}: wq_reordered duplicated across sweep entries");
            assert!(std::sync::Arc::ptr_eq(&swept[0].cka, &s.cka),
                    "{method}: cka duplicated across sweep entries");
        }
        for (s, &(kr, vr)) in swept.iter().zip(&ranks) {
            let solo = compress_layer(&mk_inp(kr, vr), cfg).unwrap();
            assert_eq!(solo.kv_perm, s.kv_perm, "{method} r=({kr},{vr}): perm");
            for (name, a, b) in [
                ("l_k", &solo.l_k, &s.l_k),
                ("l_v", &solo.l_v, &s.l_v),
                ("wo_fused", &solo.wo_fused, &s.wo_fused),
                ("wq_reordered", &*solo.wq_reordered, &*s.wq_reordered),
            ] {
                assert!(
                    bits_equal(a, b),
                    "{method} r=({kr},{vr}): {name} diverged between sweep and solo"
                );
            }
            for (a, b) in solo.r_k.iter().zip(&s.r_k) {
                assert!(bits_equal(a, b), "{method} r=({kr},{vr}): r_k diverged");
            }
            assert_eq!(solo.key_error.to_bits(), s.key_error.to_bits(), "{method} ({kr},{vr})");
            assert_eq!(
                solo.value_error_post.to_bits(),
                s.value_error_post.to_bits(),
                "{method} ({kr},{vr})"
            );
        }
    }
    pool::set_threads(0);
}
