//! Integration tests over the full runtime: artifacts → PJRT → engine,
//! including the session API acceptance bar — the streaming event loop and
//! the Coordinator's per-request streams must reproduce `run_to_completion`
//! token for token, and cancellation/deadline/backpressure must never leak
//! slots or cache pages. Skipped gracefully when artifacts/ is absent.

use recalkv::artifacts::{Manifest, TensorArchive};
use recalkv::coordinator::{
    Coordinator, Engine, EngineConfig, FinishReason, GenEvent, GenRequest, GenResult,
    SamplingParams, SubmitError,
};
use recalkv::quant::QuantKind;
use recalkv::runtime::engine_graphs::ActivationArg;
use recalkv::runtime::{GraphSet, Runtime, VariantRuntime};
use std::collections::BTreeMap;

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] artifacts/ not built");
        return None;
    }
    Some(Manifest::load(dir).unwrap())
}

#[test]
fn score_graph_matches_python_golden_logits() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let g = TensorArchive::load(man.root.join("tiny-mha/goldens.rtz")).unwrap();
    let toks_t = g.get("score.tokens").unwrap();
    let (b_g, s_g) = (toks_t.dims[0], toks_t.dims[1]);

    // run through the *score* graph with the golden tokens padded into the
    // fixed [score_batch, score_seq] shape; causality makes the first s_g
    // positions independent of the padding.
    let sb = model.shapes.score_batch;
    let ss = model.shapes.score_seq;
    let mut toks = vec![0i32; sb * ss];
    for i in 0..b_g {
        toks[i * ss..i * ss + s_g].copy_from_slice(&toks_t.i32s[i * s_g..(i + 1) * s_g]);
    }
    let v = model.config.vocab;
    for (variant, key) in [("full", "score.full_logits"), ("recal@50", "score.comp_logits")] {
        let vr = VariantRuntime::load(&rt, model.variant(variant).unwrap(), GraphSet::ScoreOnly)
            .unwrap();
        let outs = vr
            .run(vr.score_exe().unwrap(), &[ActivationArg::I32(&toks, &[sb, ss])])
            .unwrap();
        let logits = outs[0].to_vec::<f32>().unwrap();
        let want = g.f32s(key).unwrap();
        let mut max_err = 0.0f32;
        for i in 0..b_g {
            for t in 0..s_g {
                for c in 0..v {
                    let a = logits[(i * ss + t) * v + c];
                    let b = want[(i * s_g + t) * v + c];
                    max_err = max_err.max((a - b).abs());
                }
            }
        }
        assert!(max_err < 2e-3, "{variant}: rust-vs-python logits diverge by {max_err}");
    }
}

#[test]
fn engine_decode_consistent_with_score_graph() {
    // Teacher-forced continuation through the ENGINE must assign the same
    // logprobs as the score graph on the same tokens (decode==score math).
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();

    let text = "bob has a red key . the dog barks . count one two three four .";
    let toks = recalkv::coordinator::tokenizer::encode(text);
    let prompt_len = 8;

    // engine path
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    let mut req = GenRequest::new(1, toks[..prompt_len].to_vec(), toks.len() - prompt_len);
    req.forced_tokens = Some(toks[prompt_len..].to_vec());
    engine.submit(req).unwrap();
    let res = engine.run_to_completion().unwrap();
    let engine_lp = res[0].forced_logprob;

    // score path
    let vr = VariantRuntime::load(&rt, variant, GraphSet::ScoreOnly).unwrap();
    let sb = model.shapes.score_batch;
    let ss = model.shapes.score_seq;
    let mut batch = vec![0i32; sb * ss];
    batch[..toks.len()].copy_from_slice(&toks);
    let outs = vr
        .run(vr.score_exe().unwrap(), &[ActivationArg::I32(&batch, &[sb, ss])])
        .unwrap();
    let logits = outs[0].to_vec::<f32>().unwrap();
    let v = model.config.vocab;
    let mut score_lp = 0.0f64;
    for t in prompt_len - 1..toks.len() - 1 {
        let row = &logits[t * v..(t + 1) * v];
        score_lp += recalkv::coordinator::sampler::log_prob(row, toks[t + 1]);
    }
    let diff = (engine_lp - score_lp).abs();
    assert!(
        diff < 0.02 * score_lp.abs().max(1.0),
        "engine {engine_lp} vs score {score_lp} (diff {diff})"
    );
}

#[test]
fn engine_serves_batched_requests_all_variants_kinds() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    for vname in ["full", "recal@50"] {
        let variant = model.variant(vname).unwrap();
        let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
        for i in 0..6 {
            let prompt = recalkv::coordinator::tokenizer::encode("the dog ");
            engine.submit(GenRequest::new(i, prompt, 5)).unwrap();
        }
        let results = engine.run_to_completion().unwrap();
        assert_eq!(results.len(), 6, "{vname}: all requests must finish");
        for r in &results {
            assert_eq!(r.tokens.len(), 5, "{vname}: wrong generation length");
        }
        assert!(engine.cache.blocks_in_use() == 0, "{vname}: cache leak");
        assert!(engine.metrics.mean_batch_occupancy() > 0.5, "{vname}: poor batching");
    }
}

#[test]
fn quantized_engine_still_generates_sensibly() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    for quant in [QuantKind::Int4, QuantKind::Int3] {
        let mut engine =
            Engine::new(&rt, model, variant, EngineConfig { quant, ..Default::default() })
                .unwrap();
        // strongly-learned pattern (with in-distribution leading context):
        // "... . the dog " -> "barks"
        engine
            .submit(GenRequest::new(
                1,
                recalkv::coordinator::tokenizer::encode("rain fell on the old roof . the dog "),
                5,
            ))
            .unwrap();
        let res = engine.run_to_completion().unwrap();
        // int4/int3 latents perturb the greedy path after a couple of
        // characters (Table 4 quantifies the ppl cost); the prediction must
        // still start like the learned continuation and stay text-like.
        assert!(
            res[0].text.starts_with('b'),
            "{quant:?} broke a strongly-learned pattern: {:?}",
            res[0].text
        );
        assert!(
            res[0].text.bytes().all(|b| b.is_ascii_lowercase() || b == b' ' || b == b'.'),
            "{quant:?} produced non-text bytes: {:?}",
            res[0].text
        );
    }
}

#[test]
fn engine_incremental_staging_matches_full_gather_every_step() {
    // The tentpole invariant: after every scheduling step, each active
    // slot's incrementally-maintained staging region must be bit-identical
    // to a fresh full gather from the paged cache — in f32 and int4 modes.
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    for quant in [QuantKind::F32, QuantKind::Int4] {
        let mut engine =
            Engine::new(&rt, model, variant, EngineConfig { quant, ..Default::default() })
                .unwrap();
        for i in 0..4 {
            let prompt = recalkv::coordinator::tokenizer::encode("the dog barks . ");
            engine.submit(GenRequest::new(i, prompt, 6)).unwrap();
        }
        let mut steps = 0usize;
        while !engine.idle() {
            engine.step().unwrap();
            engine.check_staging_equivalence().unwrap();
            steps += 1;
            assert!(steps < 10_000, "{quant:?}: engine failed to make progress");
        }
        let results = engine.take_finished();
        assert_eq!(results.len(), 4, "{quant:?}: all requests must finish");
        assert!(results.iter().all(|r| r.error.is_none()), "{quant:?}: unexpected failure");
        // decode staging must be incremental: full gathers happen only at
        // admission, not per decode step
        assert!(
            engine.metrics.rows_staged_incr > 0,
            "{quant:?}: no incremental staging recorded"
        );
    }
}

#[test]
fn prefill_admission_failure_fails_request_and_frees() {
    // A prompt larger than the whole block pool can never be admitted: its
    // partial sequence must be freed, the request must come back as an
    // error result, and other requests in the batch must still be served.
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let mut engine = Engine::new(
        &rt,
        model,
        variant,
        EngineConfig { tokens_per_block: 4, capacity_tokens: 8, ..Default::default() },
    )
    .unwrap();
    // 16 tokens > 8-token pool: admission always fails mid-prompt.
    let doomed = recalkv::coordinator::tokenizer::encode("the dog barks . ");
    assert!(doomed.len() > 8);
    // 4 tokens (+1 decode row) fit comfortably.
    let viable = recalkv::coordinator::tokenizer::encode("dog ");
    engine.submit(GenRequest::new(1, doomed, 4)).unwrap();
    engine.submit(GenRequest::new(2, viable, 2)).unwrap();
    let mut results = engine.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 2, "every submitted request must get a result");
    let err = results[0].error.as_deref().expect("oversized request must fail admission");
    assert!(err.contains("admission"), "unexpected error text: {err}");
    assert!(results[1].error.is_none(), "viable request poisoned by batchmate: {:?}",
            results[1].error);
    assert_eq!(results[1].tokens.len(), 2);
    assert_eq!(engine.cache.blocks_in_use(), 0, "admission failure leaked blocks");
    assert_eq!(engine.cache.live_seqs(), 0, "admission failure leaked sequences");
}

#[test]
fn invalid_prompt_fails_only_its_own_request() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    engine.submit(GenRequest::new(1, vec![], 3)).unwrap(); // empty prompt
    engine
        .submit(GenRequest::new(2, recalkv::coordinator::tokenizer::encode("the dog "), 3))
        .unwrap();
    let mut results = engine.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 2);
    assert!(results[0].error.as_deref().unwrap_or("").contains("empty prompt"));
    assert!(results[1].error.is_none());
    assert_eq!(results[1].tokens.len(), 3);
    assert_eq!(engine.cache.live_seqs(), 0);
}

#[test]
fn request_can_fill_cache_exactly() {
    // Off-by-one regression: the pending token still has a free row at
    // cache_len - 1, so a request must be able to generate until the cache
    // is exactly full — cache_len - prompt_len + 1 tokens (the final
    // sampled token is never cached).
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let s = model.shapes.cache_len;
    let prompt = recalkv::coordinator::tokenizer::encode("the dog ");
    let plen = prompt.len();
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    engine.submit(GenRequest::new(1, prompt, s)).unwrap(); // more than can ever fit
    let results = engine.run_to_completion().unwrap();
    assert!(results[0].error.is_none(), "unexpected failure: {:?}", results[0].error);
    assert_eq!(
        results[0].tokens.len(),
        s - plen + 1,
        "generation must run to exact cache capacity"
    );
    assert_eq!(engine.cache.blocks_in_use(), 0);
}

/// Mixed-mode workload for the equivalence tests: greedy, seeded sampling,
/// teacher forcing, a stop token, and one invalid request — same seeds on
/// every engine, so any schedule- or API-level divergence shows up as a
/// token mismatch.
fn mixed_workload() -> Vec<GenRequest> {
    let enc = recalkv::coordinator::tokenizer::encode;
    let mut reqs = Vec::new();
    for i in 0..6u64 {
        let mut req = GenRequest::new(i + 1, enc("the dog barks . the cat "), 8);
        match i % 3 {
            0 => {} // greedy
            1 => {
                req.sampling = SamplingParams { temperature: 0.8, top_k: 4, seed: 11 + i };
            }
            _ => req.forced_tokens = Some(enc("sits on the mat")[..8].to_vec()),
        }
        if i == 5 {
            req.stop_token = Some(b' ' as i32);
        }
        reqs.push(req);
    }
    reqs.push(GenRequest::new(7, vec![], 3)); // invalid: must fail identically
    reqs
}

fn assert_results_equivalent(label: &str, a: &GenResult, b: &GenResult) {
    assert_eq!(a.id, b.id, "{label}: id");
    assert_eq!(a.tokens, b.tokens, "{label} req {}: tokens diverged", a.id);
    assert_eq!(a.text, b.text, "{label} req {}: text diverged", a.id);
    assert_eq!(
        a.forced_logprob.to_bits(),
        b.forced_logprob.to_bits(),
        "{label} req {}: forced logprob diverged",
        a.id
    );
    assert_eq!(a.forced_count, b.forced_count, "{label} req {}", a.id);
    assert_eq!(a.error, b.error, "{label} req {}: error diverged", a.id);
    assert_eq!(a.reason, b.reason, "{label} req {}: reason diverged", a.id);
    assert_eq!(a.prompt_len, b.prompt_len, "{label} req {}", a.id);
}

/// Acceptance bar for the session redesign: the event-loop driver
/// (`step` + `poll_events`) and the Coordinator's per-request streams must
/// yield token-for-token the results `run_to_completion` yields on the
/// same seeds — and the streamed `Token` events must concatenate to
/// exactly the terminal result.
#[test]
fn streaming_paths_behavior_equivalent_to_run_to_completion() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();

    // Reference: the compatibility wrapper.
    let mut engine_a = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    for req in mixed_workload() {
        engine_a.submit(req).unwrap();
    }
    let mut ref_results = engine_a.run_to_completion().unwrap();
    ref_results.sort_by_key(|r| r.id);
    assert_eq!(ref_results.len(), 7);

    // Driver 1: explicit event loop.
    let mut engine_b = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    for req in mixed_workload() {
        engine_b.submit(req).unwrap();
    }
    let mut streamed_tokens: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
    let mut streamed_text: BTreeMap<u64, String> = BTreeMap::new();
    let mut results_b: BTreeMap<u64, GenResult> = BTreeMap::new();
    while !engine_b.idle() {
        engine_b.step().unwrap();
        for ev in engine_b.poll_events() {
            match ev {
                GenEvent::Token { id, token, text_delta, .. } => {
                    streamed_tokens.entry(id).or_default().push(token);
                    streamed_text.entry(id).or_default().push_str(&text_delta);
                }
                GenEvent::Finished(r)
                | GenEvent::Failed(r)
                | GenEvent::Cancelled(r)
                | GenEvent::DeadlineExceeded(r) => {
                    assert!(results_b.insert(r.id, r).is_none(), "double terminal event");
                }
                GenEvent::Queued { .. } | GenEvent::Prefilled { .. } => {}
            }
        }
    }
    assert_eq!(results_b.len(), ref_results.len(), "event loop lost requests");
    for r in &ref_results {
        let b = &results_b[&r.id];
        assert_results_equivalent("poll_events", r, b);
        // the streamed deltas must reassemble the terminal result exactly
        let toks = streamed_tokens.get(&r.id).cloned().unwrap_or_default();
        assert_eq!(toks, b.tokens, "req {}: streamed tokens != final tokens", r.id);
        let text = streamed_text.get(&r.id).cloned().unwrap_or_default();
        assert_eq!(text, b.text, "req {}: streamed text != final text", r.id);
    }
    assert_eq!(engine_b.cache.blocks_in_use(), 0);

    // Driver 2: threaded Coordinator with per-request streams.
    let dir = man.root.clone();
    let coord = Coordinator::spawn(move || {
        let man = Manifest::load(&dir)?;
        let rt = Runtime::cpu()?;
        let model = man.model("tiny-mha")?;
        Engine::new(&rt, model, model.variant("recal@50")?, EngineConfig::default())
    });
    let streams: Vec<_> = mixed_workload().into_iter().map(|r| coord.submit(r)).collect();
    let mut results_c: Vec<GenResult> =
        streams.into_iter().map(|s| s.wait().expect("stream truncated")).collect();
    results_c.sort_by_key(|r| r.id);
    for (r, c) in ref_results.iter().zip(&results_c) {
        assert_results_equivalent("coordinator", r, c);
    }
    coord.shutdown().unwrap();
}

#[test]
fn cancel_mid_flight_frees_slot_and_pages() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    let enc = recalkv::coordinator::tokenizer::encode;
    for i in 1..=3u64 {
        engine.submit(GenRequest::new(i, enc("the dog barks . "), 20)).unwrap();
    }
    // unknown ids are a no-op
    assert!(!engine.cancel(99));
    // drive until request 2 has streamed at least two tokens, then cancel it
    let mut toks_2 = 0usize;
    let mut guard = 0usize;
    while toks_2 < 2 {
        engine.step().unwrap();
        for ev in engine.poll_events() {
            if let GenEvent::Token { id: 2, .. } = ev {
                toks_2 += 1;
            }
        }
        guard += 1;
        assert!(guard < 1000, "request 2 never produced tokens");
    }
    assert!(engine.cancel(2), "live request must be cancellable");
    assert!(!engine.cancel(2), "second cancel is a no-op");
    let mut results = engine.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 3, "every session ends in exactly one terminal result");
    assert_eq!(results[1].reason, FinishReason::Cancelled);
    assert!(
        results[1].tokens.len() >= 2 && results[1].tokens.len() < 20,
        "cancelled mid-flight: partial tokens expected, got {}",
        results[1].tokens.len()
    );
    for r in [&results[0], &results[2]] {
        assert_eq!(r.reason, FinishReason::Completed, "batch-mates must be unaffected");
        assert_eq!(r.tokens.len(), 20);
    }
    assert_eq!(engine.metrics.requests_cancelled, 1);
    assert_eq!(engine.metrics.requests_completed, 2);
    assert_eq!(engine.cache.blocks_in_use(), 0, "cancellation leaked pages");
    assert_eq!(engine.cache.live_seqs(), 0, "cancellation leaked sequences");

    // cancelling while still waiting (before any step) also reclaims
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    engine.submit(GenRequest::new(1, enc("the dog "), 4)).unwrap();
    engine.submit(GenRequest::new(2, enc("the cat "), 4)).unwrap();
    assert!(engine.cancel(2));
    let evs = engine.poll_events();
    let cancelled: Vec<_> = evs
        .iter()
        .filter_map(|e| match e {
            GenEvent::Cancelled(r) => Some(r.clone()),
            _ => None,
        })
        .collect();
    assert_eq!(cancelled.len(), 1);
    assert_eq!(cancelled[0].id, 2);
    assert!(cancelled[0].tokens.is_empty(), "waiting request has no tokens");
    assert_eq!(cancelled[0].reason, FinishReason::Cancelled);
    let results = engine.run_to_completion().unwrap();
    assert_eq!(results.len(), 1, "only the live request remains");
    assert_eq!(results[0].id, 1);
    assert_eq!(engine.cache.blocks_in_use(), 0);
}

#[test]
fn cancel_result_carries_partial_generation() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    let enc = recalkv::coordinator::tokenizer::encode;
    engine.submit(GenRequest::new(1, enc("the dog barks . "), 50)).unwrap();
    let mut streamed = Vec::new();
    let mut guard = 0usize;
    while streamed.len() < 3 {
        engine.step().unwrap();
        for ev in engine.poll_events() {
            if let GenEvent::Token { token, .. } = ev {
                streamed.push(token);
            }
        }
        guard += 1;
        assert!(guard < 1000);
    }
    engine.cancel(1);
    let res: Vec<_> = engine
        .poll_events()
        .into_iter()
        .filter_map(GenEvent::into_result)
        .collect();
    assert_eq!(res.len(), 1);
    assert_eq!(res[0].reason, FinishReason::Cancelled);
    assert!(res[0].error.is_none(), "cancellation is not an error");
    assert_eq!(res[0].tokens, streamed, "partial tokens must match the streamed prefix");
    assert!(engine.idle());
    assert_eq!(engine.cache.blocks_in_use(), 0);
}

#[test]
fn deadline_exceeded_in_waiting_and_decoding_states() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let enc = recalkv::coordinator::tokenizer::encode;

    // Waiting state: an already-expired deadline is shed at the next step,
    // before prefill ever runs.
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    engine.submit(GenRequest::new(1, enc("the dog "), 4).with_deadline_ms(0)).unwrap();
    engine.submit(GenRequest::new(2, enc("the cat "), 4)).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(2));
    let mut results = engine.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].reason, FinishReason::DeadlineExceeded);
    assert!(results[0].tokens.is_empty(), "expired while waiting: no tokens");
    assert!(results[0].error.as_deref().unwrap_or("").contains("deadline"));
    assert_eq!(results[1].reason, FinishReason::Completed);
    assert_eq!(engine.metrics.requests_expired, 1);
    assert_eq!(engine.cache.blocks_in_use(), 0);
    assert_eq!(engine.cache.live_seqs(), 0);

    // Decoding state: admitted, streams some tokens, then blows the bound
    // mid-generation; the terminal result keeps the partial output and the
    // pages come back.
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    engine
        .submit(GenRequest::new(1, enc("the dog barks . "), 10_000).with_deadline_ms(60))
        .unwrap();
    let mut saw_prefill = false;
    let mut guard = 0usize;
    while !saw_prefill {
        engine.step().unwrap();
        saw_prefill = engine
            .poll_events()
            .iter()
            .any(|e| matches!(e, GenEvent::Prefilled { .. }));
        guard += 1;
        assert!(guard < 1000, "request never admitted");
    }
    std::thread::sleep(std::time::Duration::from_millis(80));
    let results = engine.run_to_completion().unwrap();
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].reason, FinishReason::DeadlineExceeded);
    assert!(
        !results[0].tokens.is_empty(),
        "decoding expiry must preserve the partial generation"
    );
    assert_eq!(engine.metrics.requests_expired, 1);
    assert_eq!(engine.cache.blocks_in_use(), 0, "expiry leaked pages");
    assert_eq!(engine.cache.live_seqs(), 0, "expiry leaked sequences");
}

#[test]
fn queue_full_backpressure_rejects_then_admits_after_drain() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let enc = recalkv::coordinator::tokenizer::encode;
    let mut engine = Engine::new(
        &rt,
        model,
        variant,
        EngineConfig { queue_cap: 2, ..Default::default() },
    )
    .unwrap();
    engine.submit(GenRequest::new(1, enc("the dog "), 3)).unwrap();
    engine.submit(GenRequest::new(2, enc("the cat "), 3)).unwrap();
    let err = engine.submit(GenRequest::new(3, enc("the fox "), 3)).unwrap_err();
    let SubmitError::QueueFull { req, capacity } = err else {
        panic!("saturated queue must reject with QueueFull, got {err:?}");
    };
    assert_eq!(capacity, 2);
    assert_eq!(req.id, 3, "rejected request must come back for retry");
    assert_eq!(engine.metrics.requests_rejected, 1);
    // drain the queue (one prefill admits the waiters), then the retry fits
    engine.step().unwrap();
    assert_eq!(engine.queue_depth(), 0, "prefill should have admitted the queue");
    engine.submit(req).unwrap();
    let mut results = engine.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 3, "retried request must be served");
    assert!(results.iter().all(|r| r.error.is_none()));
    assert_eq!(engine.cache.blocks_in_use(), 0);
}

#[test]
fn oversized_request_rejected_at_submit() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let enc = recalkv::coordinator::tokenizer::encode;
    let mut engine = Engine::new(
        &rt,
        model,
        variant,
        EngineConfig { max_cache_tokens: 16, ..Default::default() },
    )
    .unwrap();
    // 12 prompt tokens + 8 new = 20 > 16: typed rejection, nothing queued
    let err = engine.submit(GenRequest::new(1, enc("twelve bytes"), 8)).unwrap_err();
    let SubmitError::TooLarge { req, need, budget } = err else {
        panic!("expected TooLarge, got {err:?}");
    };
    assert_eq!((need, budget), (20, 16));
    assert_eq!(req.id, 1, "rejected request must come back intact");
    assert_eq!(engine.queue_depth(), 0, "oversized request must not be queued");
    assert_eq!(engine.metrics.requests_rejected, 1);
    // exactly at budget (12 + 4) is admitted and served
    engine.submit(GenRequest::new(2, enc("twelve bytes"), 4)).unwrap();
    let results = engine.run_to_completion().unwrap();
    assert_eq!(results.len(), 1);
    assert!(results[0].error.is_none());
    assert_eq!(results[0].tokens.len(), 4);
    assert_eq!(engine.cache.blocks_in_use(), 0);
}

#[test]
fn priority_orders_admission_under_full_policy() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let enc = recalkv::coordinator::tokenizer::encode;
    // prefill_batch bounds one admission wave; with more waiters than one
    // wave admits, the high-priority latecomer must jump the FIFO order
    let pb = model.shapes.prefill_batch;
    let n = pb + 2;
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    for i in 0..n as u64 {
        let mut req = GenRequest::new(i + 1, enc("the dog "), 2);
        if i == n as u64 - 1 {
            req = req.with_priority(10); // submitted last, must admit first
        }
        engine.submit(req).unwrap();
    }
    let mut first_wave: Vec<u64> = Vec::new();
    engine.step().unwrap(); // one prefill
    for ev in engine.poll_events() {
        if let GenEvent::Prefilled { id, .. } = ev {
            first_wave.push(id);
        }
    }
    assert!(
        first_wave.contains(&(n as u64)),
        "high-priority request missing from first admission wave {first_wave:?}"
    );
    let results = engine.run_to_completion().unwrap();
    assert_eq!(results.len(), n, "every request must still be served");
    assert_eq!(engine.cache.blocks_in_use(), 0);
}

/// Drive one request to its terminal result, collecting the bit patterns
/// of every streamed token logprob — the oracle for prefix-cache identity.
fn run_one(engine: &mut Engine, req: GenRequest) -> (GenResult, Vec<u64>) {
    engine.submit(req).unwrap();
    let mut lp_bits = Vec::new();
    let mut result = None;
    while !engine.idle() {
        engine.step().unwrap();
        for ev in engine.poll_events() {
            if let GenEvent::Token { logprob, .. } = &ev {
                lp_bits.push(logprob.to_bits());
            }
            if let Some(r) = ev.into_result() {
                assert!(result.replace(r).is_none(), "double terminal result");
            }
        }
    }
    (result.expect("request never reached a terminal result"), lp_bits)
}

/// The prefix-cache acceptance bar: a request that attaches a cached
/// prefix must be byte-for-byte identical to the same request served cold
/// — tokens, text, and every streamed logprob bit — and retiring all
/// sequences must leave exactly the trie-held pages allocated.
#[test]
fn prefix_cache_hit_is_bitwise_identical_to_cold_prefill() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let prompt = recalkv::coordinator::tokenizer::encode("the dog barks . the cat sits . ");

    // Small pages so the short prompt spans full chunks (only full pages
    // are shareable); identical paging on both engines, so the one delta
    // between the worlds is the prefix cache itself.
    let paging = EngineConfig { tokens_per_block: 4, ..Default::default() };

    // cold reference: prefix cache off, both requests prefill from scratch
    let mut cold = Engine::new(&rt, model, variant, paging.clone()).unwrap();
    let (cold1, cold1_lp) = run_one(&mut cold, GenRequest::new(1, prompt.clone(), 8));
    let (cold2, cold2_lp) = run_one(&mut cold, GenRequest::new(2, prompt.clone(), 8));
    assert_eq!(cold.cache.blocks_in_use(), 0);

    // warm: the first request seeds the trie, the second attaches it
    let mut warm = Engine::new(
        &rt,
        model,
        variant,
        EngineConfig { prefix_cache_pages: 256, ..paging },
    )
    .unwrap();
    let (warm1, warm1_lp) = run_one(&mut warm, GenRequest::new(1, prompt.clone(), 8));
    let (warm2, warm2_lp) = run_one(&mut warm, GenRequest::new(2, prompt.clone(), 8));
    assert_eq!(warm.metrics.prefix_misses, 1, "first request must miss");
    assert_eq!(warm.metrics.prefix_hits, 1, "second request must hit");
    assert!(warm.metrics.prefix_pages_shared > 0, "a hit must adopt pages");

    assert_results_equivalent("prefix miss vs cold", &cold1, &warm1);
    assert_results_equivalent("prefix hit vs cold", &cold2, &warm2);
    assert_eq!(cold1_lp, warm1_lp, "miss-path logprob bits diverged from cold");
    assert_eq!(cold2_lp, warm2_lp, "hit-path logprob bits diverged from cold");

    // all sequences retired: the only pages still allocated are the ones
    // the trie deliberately holds (shared-page accounting is exact)
    assert!(warm.prefix_pages_held() > 0, "trie should hold the shared prefix");
    assert_eq!(
        warm.cache.blocks_in_use(),
        warm.prefix_pages_held(),
        "pages beyond the trie's leaked"
    );
    assert_eq!(warm.cache.live_seqs(), 0);
}

#[test]
fn gqa_model_serves() {
    let Some(man) = manifest() else { return };
    if !man.models.contains_key("tiny-gqa") {
        eprintln!("[skip] tiny-gqa not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-gqa").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    engine
        .submit(GenRequest::new(1, recalkv::coordinator::tokenizer::encode("the cat "), 5))
        .unwrap();
    let res = engine.run_to_completion().unwrap();
    assert_eq!(res.len(), 1);
    assert_eq!(res[0].tokens.len(), 5);
}
