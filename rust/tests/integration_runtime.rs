//! Integration tests over the full runtime: artifacts → PJRT → engine.
//! Skipped gracefully when artifacts/ is absent.

use recalkv::artifacts::{Manifest, TensorArchive};
use recalkv::coordinator::{Engine, EngineConfig, GenRequest};
use recalkv::quant::QuantKind;
use recalkv::runtime::engine_graphs::ActivationArg;
use recalkv::runtime::{GraphSet, Runtime, VariantRuntime};

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] artifacts/ not built");
        return None;
    }
    Some(Manifest::load(dir).unwrap())
}

#[test]
fn score_graph_matches_python_golden_logits() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let g = TensorArchive::load(man.root.join("tiny-mha/goldens.rtz")).unwrap();
    let toks_t = g.get("score.tokens").unwrap();
    let (b_g, s_g) = (toks_t.dims[0], toks_t.dims[1]);

    // run through the *score* graph with the golden tokens padded into the
    // fixed [score_batch, score_seq] shape; causality makes the first s_g
    // positions independent of the padding.
    let sb = model.shapes.score_batch;
    let ss = model.shapes.score_seq;
    let mut toks = vec![0i32; sb * ss];
    for i in 0..b_g {
        toks[i * ss..i * ss + s_g].copy_from_slice(&toks_t.i32s[i * s_g..(i + 1) * s_g]);
    }
    let v = model.config.vocab;
    for (variant, key) in [("full", "score.full_logits"), ("recal@50", "score.comp_logits")] {
        let vr = VariantRuntime::load(&rt, model.variant(variant).unwrap(), GraphSet::ScoreOnly)
            .unwrap();
        let outs = vr
            .run(vr.score_exe().unwrap(), &[ActivationArg::I32(&toks, &[sb, ss])])
            .unwrap();
        let logits = outs[0].to_vec::<f32>().unwrap();
        let want = g.f32s(key).unwrap();
        let mut max_err = 0.0f32;
        for i in 0..b_g {
            for t in 0..s_g {
                for c in 0..v {
                    let a = logits[(i * ss + t) * v + c];
                    let b = want[(i * s_g + t) * v + c];
                    max_err = max_err.max((a - b).abs());
                }
            }
        }
        assert!(max_err < 2e-3, "{variant}: rust-vs-python logits diverge by {max_err}");
    }
}

#[test]
fn engine_decode_consistent_with_score_graph() {
    // Teacher-forced continuation through the ENGINE must assign the same
    // logprobs as the score graph on the same tokens (decode==score math).
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();

    let text = "bob has a red key . the dog barks . count one two three four .";
    let toks = recalkv::coordinator::tokenizer::encode(text);
    let prompt_len = 8;

    // engine path
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    let mut req = GenRequest::new(1, toks[..prompt_len].to_vec(), toks.len() - prompt_len);
    req.forced_tokens = Some(toks[prompt_len..].to_vec());
    engine.submit(req);
    let res = engine.run_to_completion().unwrap();
    let engine_lp = res[0].forced_logprob;

    // score path
    let vr = VariantRuntime::load(&rt, variant, GraphSet::ScoreOnly).unwrap();
    let sb = model.shapes.score_batch;
    let ss = model.shapes.score_seq;
    let mut batch = vec![0i32; sb * ss];
    batch[..toks.len()].copy_from_slice(&toks);
    let outs = vr
        .run(vr.score_exe().unwrap(), &[ActivationArg::I32(&batch, &[sb, ss])])
        .unwrap();
    let logits = outs[0].to_vec::<f32>().unwrap();
    let v = model.config.vocab;
    let mut score_lp = 0.0f64;
    for t in prompt_len - 1..toks.len() - 1 {
        let row = &logits[t * v..(t + 1) * v];
        score_lp += recalkv::coordinator::sampler::log_prob(row, toks[t + 1]);
    }
    let diff = (engine_lp - score_lp).abs();
    assert!(
        diff < 0.02 * score_lp.abs().max(1.0),
        "engine {engine_lp} vs score {score_lp} (diff {diff})"
    );
}

#[test]
fn engine_serves_batched_requests_all_variants_kinds() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    for vname in ["full", "recal@50"] {
        let variant = model.variant(vname).unwrap();
        let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
        for i in 0..6 {
            let prompt = recalkv::coordinator::tokenizer::encode("the dog ");
            engine.submit(GenRequest::new(i, prompt, 5));
        }
        let results = engine.run_to_completion().unwrap();
        assert_eq!(results.len(), 6, "{vname}: all requests must finish");
        for r in &results {
            assert_eq!(r.tokens.len(), 5, "{vname}: wrong generation length");
        }
        assert!(engine.cache.blocks_in_use() == 0, "{vname}: cache leak");
        assert!(engine.metrics.mean_batch_occupancy() > 0.5, "{vname}: poor batching");
    }
}

#[test]
fn quantized_engine_still_generates_sensibly() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    for quant in [QuantKind::Int4, QuantKind::Int3] {
        let mut engine =
            Engine::new(&rt, model, variant, EngineConfig { quant, ..Default::default() })
                .unwrap();
        // strongly-learned pattern (with in-distribution leading context):
        // "... . the dog " -> "barks"
        engine.submit(GenRequest::new(
            1,
            recalkv::coordinator::tokenizer::encode("rain fell on the old roof . the dog "),
            5,
        ));
        let res = engine.run_to_completion().unwrap();
        // int4/int3 latents perturb the greedy path after a couple of
        // characters (Table 4 quantifies the ppl cost); the prediction must
        // still start like the learned continuation and stay text-like.
        assert!(
            res[0].text.starts_with('b'),
            "{quant:?} broke a strongly-learned pattern: {:?}",
            res[0].text
        );
        assert!(
            res[0].text.bytes().all(|b| b.is_ascii_lowercase() || b == b' ' || b == b'.'),
            "{quant:?} produced non-text bytes: {:?}",
            res[0].text
        );
    }
}

#[test]
fn engine_incremental_staging_matches_full_gather_every_step() {
    // The tentpole invariant: after every scheduling step, each active
    // slot's incrementally-maintained staging region must be bit-identical
    // to a fresh full gather from the paged cache — in f32 and int4 modes.
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    for quant in [QuantKind::F32, QuantKind::Int4] {
        let mut engine =
            Engine::new(&rt, model, variant, EngineConfig { quant, ..Default::default() })
                .unwrap();
        for i in 0..4 {
            let prompt = recalkv::coordinator::tokenizer::encode("the dog barks . ");
            engine.submit(GenRequest::new(i, prompt, 6));
        }
        let mut steps = 0usize;
        while !engine.idle() {
            engine.step().unwrap();
            engine.check_staging_equivalence().unwrap();
            steps += 1;
            assert!(steps < 10_000, "{quant:?}: engine failed to make progress");
        }
        let results = engine.take_finished();
        assert_eq!(results.len(), 4, "{quant:?}: all requests must finish");
        assert!(results.iter().all(|r| r.error.is_none()), "{quant:?}: unexpected failure");
        // decode staging must be incremental: full gathers happen only at
        // admission, not per decode step
        assert!(
            engine.metrics.rows_staged_incr > 0,
            "{quant:?}: no incremental staging recorded"
        );
    }
}

#[test]
fn prefill_admission_failure_fails_request_and_frees() {
    // A prompt larger than the whole block pool can never be admitted: its
    // partial sequence must be freed, the request must come back as an
    // error result, and other requests in the batch must still be served.
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let mut engine = Engine::new(
        &rt,
        model,
        variant,
        EngineConfig { tokens_per_block: 4, capacity_tokens: 8, ..Default::default() },
    )
    .unwrap();
    // 16 tokens > 8-token pool: admission always fails mid-prompt.
    let doomed = recalkv::coordinator::tokenizer::encode("the dog barks . ");
    assert!(doomed.len() > 8);
    // 4 tokens (+1 decode row) fit comfortably.
    let viable = recalkv::coordinator::tokenizer::encode("dog ");
    engine.submit(GenRequest::new(1, doomed, 4));
    engine.submit(GenRequest::new(2, viable, 2));
    let mut results = engine.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 2, "every submitted request must get a result");
    let err = results[0].error.as_deref().expect("oversized request must fail admission");
    assert!(err.contains("admission"), "unexpected error text: {err}");
    assert!(results[1].error.is_none(), "viable request poisoned by batchmate: {:?}",
            results[1].error);
    assert_eq!(results[1].tokens.len(), 2);
    assert_eq!(engine.cache.blocks_in_use(), 0, "admission failure leaked blocks");
    assert_eq!(engine.cache.live_seqs(), 0, "admission failure leaked sequences");
}

#[test]
fn invalid_prompt_fails_only_its_own_request() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    engine.submit(GenRequest::new(1, vec![], 3)); // empty prompt
    engine.submit(GenRequest::new(2, recalkv::coordinator::tokenizer::encode("the dog "), 3));
    let mut results = engine.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results.len(), 2);
    assert!(results[0].error.as_deref().unwrap_or("").contains("empty prompt"));
    assert!(results[1].error.is_none());
    assert_eq!(results[1].tokens.len(), 3);
    assert_eq!(engine.cache.live_seqs(), 0);
}

#[test]
fn request_can_fill_cache_exactly() {
    // Off-by-one regression: the pending token still has a free row at
    // cache_len - 1, so a request must be able to generate until the cache
    // is exactly full — cache_len - prompt_len + 1 tokens (the final
    // sampled token is never cached).
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-mha").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let s = model.shapes.cache_len;
    let prompt = recalkv::coordinator::tokenizer::encode("the dog ");
    let plen = prompt.len();
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    engine.submit(GenRequest::new(1, prompt, s)); // more than can ever fit
    let results = engine.run_to_completion().unwrap();
    assert!(results[0].error.is_none(), "unexpected failure: {:?}", results[0].error);
    assert_eq!(
        results[0].tokens.len(),
        s - plen + 1,
        "generation must run to exact cache capacity"
    );
    assert_eq!(engine.cache.blocks_in_use(), 0);
}

#[test]
fn gqa_model_serves() {
    let Some(man) = manifest() else { return };
    if !man.models.contains_key("tiny-gqa") {
        eprintln!("[skip] tiny-gqa not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let model = man.model("tiny-gqa").unwrap();
    let variant = model.variant("recal@50").unwrap();
    let mut engine = Engine::new(&rt, model, variant, EngineConfig::default()).unwrap();
    engine.submit(GenRequest::new(1, recalkv::coordinator::tokenizer::encode("the cat "), 5));
    let res = engine.run_to_completion().unwrap();
    assert_eq!(res.len(), 1);
    assert_eq!(res[0].tokens.len(), 5);
}
