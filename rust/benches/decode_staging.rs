//! Decode-staging microbench: per-step staging cost of the old full
//! re-gather (O(S·w) per layer per token) vs the incremental path the
//! engine now uses (one O(w) row per layer per token), at context lengths
//! S ∈ {512, 2048, 8192}, in both f32 and int4 cache modes.
//!
//! The full path re-runs `KvCache::stage` over every cached position the
//! way the pre-incremental engine did on every decode step; the incremental
//! path stages exactly the one-row suffix a decode step adds
//! (`KvCache::stage_rows`, the same per-row work `append_and_stage` does
//! when it extends a slot's staging tail). Appending itself costs the same
//! in both designs and is excluded from both measurements.
//!
//! Writes a machine-readable summary (per-step times and speedups) to
//! `BENCH_decode_staging.json` (override with `--out`), so successive PRs
//! have a staging-perf trajectory to compare against:
//!
//!   cargo bench --bench decode_staging -- --out ../BENCH_decode_staging.json

use recalkv::kvcache::{CacheConfig, KvCache};
use recalkv::quant::QuantKind;
use recalkv::util::bench::{bench, Table};
use recalkv::util::cli::Args;
use recalkv::util::json::Json;
use recalkv::util::rng::Rng;
use std::collections::BTreeMap;
use std::time::Duration;

const N_LAYERS: usize = 4;
const WK: usize = 96; // g·rk
const WV: usize = 128; // rv
const TPB: usize = 32;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &["quick"]);
    let out_path = args.opt_or("out", "BENCH_decode_staging.json").to_string();
    let budget = Duration::from_millis(if args.has("quick") { 150 } else { 500 });
    let lens: Vec<usize> =
        if args.has("quick") { vec![512, 2048] } else { vec![512, 2048, 8192] };

    let mut table = Table::new(
        "Decode staging: full re-gather vs incremental tail (per step, all layers)",
        &["S", "quant", "full/step", "incr/step", "speedup"],
    );
    let mut results = Vec::new();
    for &s in &lens {
        for quant in [QuantKind::F32, QuantKind::Int4] {
            let mut rng = Rng::new(0x5eed ^ s as u64);
            let mut cache = KvCache::new(CacheConfig {
                n_layers: N_LAYERS,
                widths: vec![(WK, WV); N_LAYERS],
                cache_len: s,
                tokens_per_block: TPB,
                capacity_tokens: s + TPB,
                quant,
                signs_seed: 7,
            });
            let seq = cache.new_seq();
            let k: Vec<f32> = (0..WK).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..WV).map(|_| rng.normal()).collect();
            for _ in 0..s {
                let rows: Vec<(&[f32], &[f32])> =
                    (0..N_LAYERS).map(|_| (&k[..], &v[..])).collect();
                cache.append(seq, &rows)?;
            }

            let mut kbuf = vec![0.0f32; s * WK];
            let mut vbuf = vec![0.0f32; s * WV];
            let label = format!("{quant:?}").to_lowercase();
            let full = bench(&format!("stage full  S={s} {label}"), budget, || {
                for l in 0..N_LAYERS {
                    cache.stage(seq, l, 0, &mut kbuf, s).unwrap();
                    cache.stage(seq, l, 1, &mut vbuf, s).unwrap();
                }
            });
            let incr = bench(&format!("stage incr  S={s} {label}"), budget, || {
                for l in 0..N_LAYERS {
                    cache.stage_rows(seq, l, 0, s - 1, s, &mut kbuf[..WK]).unwrap();
                    cache.stage_rows(seq, l, 1, s - 1, s, &mut vbuf[..WV]).unwrap();
                }
            });
            let speedup = full.median_ns / incr.median_ns.max(1.0);
            table.row(vec![
                s.to_string(),
                label.clone(),
                format!("{:.1} µs", full.median_ns / 1e3),
                format!("{:.2} µs", incr.median_ns / 1e3),
                format!("{speedup:.0}x"),
            ]);
            table.print_last();
            results.push(obj(vec![
                ("s", Json::Num(s as f64)),
                ("quant", Json::Str(label)),
                ("full_ns_per_step", Json::Num(full.median_ns)),
                ("incr_ns_per_step", Json::Num(incr.median_ns)),
                ("speedup", Json::Num(speedup)),
            ]));
        }
    }
    table.print();

    let report = obj(vec![
        ("bench", Json::Str("decode_staging".into())),
        (
            "config",
            obj(vec![
                ("n_layers", Json::Num(N_LAYERS as f64)),
                ("key_width", Json::Num(WK as f64)),
                ("value_width", Json::Num(WV as f64)),
                ("tokens_per_block", Json::Num(TPB as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(&out_path, report.to_string())?;
    println!("[report saved to {out_path}]");
    Ok(())
}
