//! Serving-lifecycle benchmark: the session API under a mixed
//! cancel/deadline workload at every batching policy, plus runtime-free
//! micro-paths (admission-queue ops, cancellation page reclaim).
//!
//! Emits `BENCH_serving.json` so successive PRs have a lifecycle-perf
//! trajectory: decode tok/s, mean TTFT, queue-wait p50/p95, streamed
//! token-latency p50/p95, cancellation reclaim latency (p50 of
//! `Engine::cancel` wall time), and cancelled/expired/rejected counts, at
//! `eager` / `full` / `threshold2`. The engine section needs artifacts/
//! (skipped gracefully without them); the micro section always runs.
//!
//!   cargo bench --bench serving_lifecycle -- --out ../BENCH_serving.json

use recalkv::artifacts::Manifest;
use recalkv::coordinator::batcher::{BatchPolicy, WaitQueue};
use recalkv::coordinator::metrics::Metrics;
use recalkv::coordinator::{Engine, EngineConfig, GenEvent, GenRequest, SubmitError};
use recalkv::kvcache::{CacheConfig, KvCache};
use recalkv::quant::QuantKind;
use recalkv::runtime::Runtime;
use recalkv::util::bench::{bench, Table};
use recalkv::util::cli::Args;
use recalkv::util::json::Json;
use recalkv::util::rng::Rng;
use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Cancellation reclaim without XLA: fill one sequence to S tokens, then
/// time `free_seq` (the exact page-release path `Engine::cancel` takes).
/// Destructive, so sampled by refilling between timings instead of through
/// the steady-state `bench` harness.
fn reclaim_microbench(results: &mut Vec<Json>, quick: bool) {
    let lens: &[usize] = if quick { &[512] } else { &[512, 4096] };
    for &s in lens {
        for quant in [QuantKind::F32, QuantKind::Int4] {
            let mut rng = Rng::new(0xca ^ s as u64);
            let mut cache = KvCache::new(CacheConfig {
                n_layers: 4,
                widths: vec![(96, 128); 4],
                cache_len: s,
                tokens_per_block: 32,
                capacity_tokens: s + 32,
                quant,
                signs_seed: 7,
            });
            let k: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
            let mut samples = Vec::new();
            let mut pages = 0usize;
            for _ in 0..if quick { 5 } else { 15 } {
                let seq = cache.new_seq();
                for _ in 0..s {
                    let rows: Vec<(&[f32], &[f32])> = (0..4).map(|_| (&k[..], &v[..])).collect();
                    cache.append(seq, &rows).unwrap();
                }
                let t0 = Instant::now();
                pages = cache.free_seq(seq);
                samples.push(t0.elapsed().as_secs_f64() * 1e6);
            }
            let p50 = Metrics::percentile(&samples, 0.5);
            println!(
                "reclaim S={s:<5} {quant:?}: p50 {p50:.1}µs for {pages} pages \
                 ({:.2}µs/page)",
                p50 / pages.max(1) as f64
            );
            results.push(obj(vec![
                ("s", Json::Num(s as f64)),
                ("quant", Json::Str(format!("{quant:?}").to_lowercase())),
                ("pages", Json::Num(pages as f64)),
                ("free_us_p50", Json::Num(p50)),
            ]));
        }
    }
}

/// Admission-queue ops under mixed priorities/deadlines (runtime-free).
fn wait_queue_microbench(budget: Duration) -> Json {
    let n = 256usize;
    let mut rng = Rng::new(0x9a11);
    let reqs: Vec<GenRequest> = (0..n)
        .map(|i| {
            let mut r = GenRequest::new(i as u64, vec![1], 1);
            r.priority = rng.below(3) as i32 - 1;
            if rng.below(2) == 0 {
                r.deadline_ms = Some(1_000 + rng.below(100_000) as u64);
            }
            r
        })
        .collect();
    let res = bench(&format!("wait_queue push+pop_next n={n}"), budget, || {
        let mut q = WaitQueue::new(usize::MAX);
        for r in &reqs {
            q.push(r.clone()).unwrap();
        }
        while q.pop_next().is_some() {}
    });
    obj(vec![
        ("n", Json::Num(n as f64)),
        ("push_pop_all_ns", Json::Num(res.median_ns)),
        ("ops_per_s", Json::Num(res.throughput(2.0 * n as f64))),
    ])
}

/// Mixed cancel/deadline workload through the real engine at one policy.
///
/// Per-request roles are disjoint by `i % 4` (ids are `i + 1`): `i%4==1`
/// carries a deadline, `i%4==3` is priority-boosted, `i%4==0` is cancelled
/// after its second streamed token, `i%4==2` is plain — so each measured
/// dimension (deadline shedding, priority queue-wait, cancellation
/// reclaim) is observed on requests that do nothing else.
///
/// The admission queue is bounded at half the load: submission runs
/// through a retry loop that steps the engine on every `QueueFull` bounce,
/// so the backpressure path is genuinely exercised (`rejected` below
/// counts bounces, from the engine's own counter).
fn engine_lifecycle(man: &Manifest, rt: &Runtime, policy: BatchPolicy, n_req: usize,
                    max_new: usize) -> anyhow::Result<Json> {
    let model = man.model("tiny-mha")?;
    let variant = model.variant("recal@50")?;
    let mut engine = Engine::new(
        rt,
        model,
        variant,
        EngineConfig { policy, queue_cap: (n_req / 2).max(2), ..Default::default() },
    )?;
    let insts = recalkv::eval::tasks::gen_long("needle", 42, n_req, 200);
    let mut backlog: VecDeque<GenRequest> = insts
        .iter()
        .enumerate()
        .map(|(i, inst)| {
            let mut req = GenRequest::new(
                i as u64 + 1,
                recalkv::coordinator::tokenizer::encode(&inst.prompt),
                max_new,
            );
            if i % 4 == 1 {
                // a latency bound loose enough to usually finish but tight
                // enough to shed under Full batching
                req.deadline_ms = Some(2_000);
            }
            if i % 4 == 3 {
                req.priority = 1;
            }
            req
        })
        .collect();
    // single driver loop: feed the bounded queue under backpressure, stream
    // events, cancel the `i%4==0` cohort (ids ≡ 1 mod 4) after two tokens
    let mut tokens_seen: BTreeMap<u64, usize> = BTreeMap::new();
    let mut cancel_us: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    let mut done = 0usize;
    while !backlog.is_empty() || !engine.idle() {
        while let Some(req) = backlog.pop_front() {
            match engine.submit(req) {
                Ok(_) => {}
                Err(SubmitError::QueueFull { req, .. }) => {
                    backlog.push_front(req);
                    break;
                }
                Err(e) => anyhow::bail!("unexpected submit rejection: {e}"),
            }
        }
        engine.step()?;
        let mut to_cancel = Vec::new();
        for ev in engine.poll_events() {
            match ev {
                GenEvent::Token { id, .. } => {
                    let c = tokens_seen.entry(id).or_insert(0);
                    *c += 1;
                    if *c == 2 && id % 4 == 1 {
                        to_cancel.push(id);
                    }
                }
                ev if ev.is_terminal() => done += 1,
                _ => {}
            }
        }
        for id in to_cancel {
            let t = Instant::now();
            engine.cancel(id);
            cancel_us.push(t.elapsed().as_secs_f64() * 1e6);
        }
        done += engine.poll_events().iter().filter(|e| e.is_terminal()).count();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = engine.metrics.clone();
    println!(
        "{:<11} {:>5.1} tok/s | ttft {:>6.1}ms | queue p50/p95 {:>6.1}/{:>6.1}ms | \
         cancelled {} (reclaim p50 {:.1}µs) expired {} rejected {} | {done} terminal",
        policy.name(),
        m.decode_tokens_per_s(),
        m.mean_ttft_ms(),
        m.queue_wait_pctile(0.5),
        m.queue_wait_pctile(0.95),
        m.requests_cancelled,
        Metrics::percentile(&cancel_us, 0.5),
        m.requests_expired,
        m.requests_rejected,
    );
    Ok(obj(vec![
        ("policy", Json::Str(policy.name())),
        ("requests", Json::Num(n_req as f64)),
        ("wall_s", Json::Num(wall)),
        ("decode_tok_per_s", Json::Num(m.decode_tokens_per_s())),
        ("ttft_ms_mean", Json::Num(m.mean_ttft_ms())),
        ("queue_wait_ms_p50", Json::Num(m.queue_wait_pctile(0.5))),
        ("queue_wait_ms_p95", Json::Num(m.queue_wait_pctile(0.95))),
        ("token_latency_ms_p50", Json::Num(m.token_latency_pctile(0.5))),
        ("token_latency_ms_p95", Json::Num(m.token_latency_pctile(0.95))),
        ("cancel_reclaim_us_p50", Json::Num(Metrics::percentile(&cancel_us, 0.5))),
        ("cancelled", Json::Num(m.requests_cancelled as f64)),
        ("expired", Json::Num(m.requests_expired as f64)),
        ("rejected", Json::Num(m.requests_rejected as f64)),
        ("completed", Json::Num(m.requests_completed as f64)),
        ("occupancy", Json::Num(m.mean_batch_occupancy())),
    ]))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &["quick"]);
    let out_path = args.opt_or("out", "BENCH_serving.json").to_string();
    let quick = args.has("quick");
    let budget = Duration::from_millis(if quick { 150 } else { 500 });
    let n_req = args.usize_or("requests", if quick { 8 } else { 16 });
    let max_new = args.usize_or("max-new", if quick { 8 } else { 16 });

    let mut reclaim = Vec::new();
    reclaim_microbench(&mut reclaim, quick);
    let wq = wait_queue_microbench(budget);

    let mut engine_rows = Vec::new();
    let engine_json = match Manifest::load(args.opt_or("artifacts", "artifacts")) {
        Ok(man) => {
            let rt = Runtime::cpu()?;
            let mut t = Table::new(
                "Serving lifecycle (mixed cancel/deadline workload)",
                &["policy", "tok/s", "ttft ms", "queue p50/p95 ms", "cancelled", "expired"],
            );
            for policy in [BatchPolicy::Eager, BatchPolicy::Full, BatchPolicy::Threshold(2)] {
                let row = engine_lifecycle(&man, &rt, policy, n_req, max_new)?;
                t.row(vec![
                    policy.name(),
                    format!("{:.1}", row.req("decode_tok_per_s").as_f64().unwrap_or(0.0)),
                    format!("{:.1}", row.req("ttft_ms_mean").as_f64().unwrap_or(0.0)),
                    format!(
                        "{:.1}/{:.1}",
                        row.req("queue_wait_ms_p50").as_f64().unwrap_or(0.0),
                        row.req("queue_wait_ms_p95").as_f64().unwrap_or(0.0)
                    ),
                    format!("{}", row.req("cancelled").as_f64().unwrap_or(0.0) as u64),
                    format!("{}", row.req("expired").as_f64().unwrap_or(0.0) as u64),
                ]);
                engine_rows.push(row);
            }
            t.print();
            Json::Arr(std::mem::take(&mut engine_rows))
        }
        Err(_) => {
            println!("[skip] artifacts/ not built — micro-paths only");
            Json::Null
        }
    };

    let report = obj(vec![
        ("bench", Json::Str("serving_lifecycle".into())),
        ("reclaim", Json::Arr(reclaim)),
        ("wait_queue", wq),
        ("engine", engine_json),
    ]);
    std::fs::write(&out_path, report.to_string())?;
    println!("[report saved to {out_path}]");
    Ok(())
}
