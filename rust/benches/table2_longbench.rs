//! Regenerates paper Table 2: eight long-context tasks decoded greedily
//! through the serving engine (prefill + paged latent cache + decode).
//!
//! Bench defaults are CI-sized; the full-size run is recorded in
//! artifacts/tables/e2e_run.txt (via `repro tables`). Override with e.g.
//!   cargo bench --bench table2_longbench -- --long 8

use recalkv::artifacts::Manifest;
use recalkv::eval::report::{self, EvalSizes};
use recalkv::runtime::Runtime;
use recalkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &[]);
    let man = Manifest::load(args.opt_or("artifacts", "artifacts"))?;
    let mut sizes = EvalSizes::from_manifest(&man);
    sizes.long_per_task = args.usize_or("long", 4);
    let models: Vec<String> = args
        .opt_or("models", "tiny-mha,tiny-gqa")
        .split(',')
        .map(String::from)
        .collect();
    let refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
    let rt = Runtime::cpu()?;
    let t = report::table2(&rt, &man, &refs, &sizes)?;
    t.print();
    t.save_tsv("artifacts/tables/table2.tsv");
    Ok(())
}
