//! Shard-router benchmark: the front tier fanning out over loopback
//! worker fleets, plus runtime-free placement/breaker micro-paths.
//!
//! Emits `BENCH_router.json` so successive PRs have a fan-out perf
//! trajectory: streamed tok/s and client-observed TTFT p95 end-to-end
//! through router + workers at 1/2/4 loopback workers (1/2 with --quick),
//! and the recovery profile after a worker kill — how long until the
//! first post-kill request completes through failover, and until the
//! prober trips the dead worker's breaker. The fleet section needs
//! artifacts/ (skipped gracefully without them); the micro-paths always
//! run.
//!
//!   cargo bench --bench router_fanout -- --out ../BENCH_router.json

use recalkv::artifacts::Manifest;
use recalkv::coordinator::{Coordinator, Engine, EngineConfig};
use recalkv::router::{
    place, prefix_hash, Breaker, BreakerConfig, HealthConfig, Router, RouterConfig, WorkerView,
};
use recalkv::server::{run_load, Client, Server, ServerConfig, WireEvent, WireRequest};
use recalkv::util::bench::{bench, Table};
use recalkv::util::cli::Args;
use recalkv::util::json::Json;
use std::time::{Duration, Instant};

/// Placement and breaker micro-paths (runtime-free): the per-request cost
/// the front tier adds before a single byte reaches a worker.
fn router_microbench(budget: Duration) -> Json {
    let prompt = "the dog barks . the cat sleeps . ".repeat(16);
    let hash = bench("prefix hash", budget, || {
        std::hint::black_box(prefix_hash(std::hint::black_box(&prompt)));
    });
    let views: Vec<WorkerView> = (0..16)
        .map(|i| WorkerView { index: i, eligible: i % 5 != 0, queue_depth: (i * 7) % 11 })
        .collect();
    let h = prefix_hash(&prompt);
    let placed = bench("placement over 16 workers", budget, || {
        std::hint::black_box(place(std::hint::black_box(&views), h, 2));
    });
    let cycle = bench("breaker trip/recover cycle", budget, || {
        let mut b = Breaker::new(BreakerConfig { failure_threshold: 3, open_ticks: 2 });
        for _ in 0..3 {
            b.record_failure();
        }
        for _ in 0..3 {
            b.tick();
        }
        b.record_success();
        std::hint::black_box(b.state());
    });
    Json::obj(vec![
        ("prefix_hash_ns", Json::Num(hash.median_ns)),
        ("placement_ns", Json::Num(placed.median_ns)),
        ("placements_per_s", Json::Num(placed.throughput(1.0))),
        ("breaker_cycle_ns", Json::Num(cycle.median_ns)),
    ])
}

struct Fleet {
    router_addr: String,
    workers: Vec<(String, std::sync::Arc<std::sync::atomic::AtomicBool>)>,
    router_stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    threads: Vec<std::thread::JoinHandle<anyhow::Result<()>>>,
    coords: Vec<Coordinator>,
}

/// Spawn `n` engine+server workers plus a router fronting them all.
fn spawn_fleet(dir: &str, n: usize, rcfg: RouterConfig) -> anyhow::Result<Fleet> {
    let mut workers = Vec::new();
    let mut threads = Vec::new();
    let mut coords = Vec::new();
    for _ in 0..n {
        let dir = dir.to_string();
        let coord = Coordinator::spawn(move || {
            let man = Manifest::load(&dir)?;
            let rt = recalkv::runtime::Runtime::cpu()?;
            let model = man.model("tiny-mha")?;
            Engine::new(&rt, model, model.variant("recal@50")?, EngineConfig::default())
        });
        let server = Server::bind("127.0.0.1:0", coord.handle(), ServerConfig::default())?;
        let addr = server.local_addr()?.to_string();
        workers.push((addr, server.stop_flag()));
        threads.push(std::thread::spawn(move || server.run()));
        coords.push(coord);
    }
    let addrs: Vec<String> = workers.iter().map(|(a, _)| a.clone()).collect();
    let router = Router::bind("127.0.0.1:0", &addrs, rcfg)?;
    let router_addr = router.local_addr()?.to_string();
    let router_stop = router.stop_flag();
    threads.push(std::thread::spawn(move || router.run()));
    Ok(Fleet { router_addr, workers, router_stop, threads, coords })
}

impl Fleet {
    fn shutdown(self) -> anyhow::Result<()> {
        self.router_stop.store(true, std::sync::atomic::Ordering::SeqCst);
        for (_, stop) in &self.workers {
            stop.store(true, std::sync::atomic::Ordering::SeqCst);
        }
        for t in self.threads {
            t.join().expect("fleet thread panicked")?;
        }
        for c in self.coords {
            c.shutdown()?;
        }
        Ok(())
    }
}

/// One fan-out scaling point: `clients` concurrent connections through a
/// router over `n_workers` workers.
fn fanout_point(
    dir: &str,
    n_workers: usize,
    clients: usize,
    reqs: usize,
    prompts: &[String],
    max_new: usize,
) -> anyhow::Result<Json> {
    let fleet = spawn_fleet(dir, n_workers, RouterConfig::default())?;
    let rep = run_load(&fleet.router_addr, clients, reqs, prompts, max_new)?;
    println!(
        "{:>2} workers, {:>2} clients: {:>6.1} req/s {:>7.1} tok/s | ttft p50/p95 \
         {:>6.1}/{:>6.1}ms | {} ok {} rejected {} failed",
        n_workers,
        clients,
        rep.req_per_s(),
        rep.tok_per_s(),
        rep.ttft_pctile(0.50),
        rep.ttft_pctile(0.95),
        rep.completed,
        rep.rejected,
        rep.failed,
    );
    fleet.shutdown()?;
    Ok(Json::obj(vec![
        ("workers", Json::Num(n_workers as f64)),
        ("clients", Json::Num(clients as f64)),
        ("requests", Json::Num(rep.requests as f64)),
        ("completed", Json::Num(rep.completed as f64)),
        ("rejected", Json::Num(rep.rejected as f64)),
        ("failed", Json::Num(rep.failed as f64)),
        ("wall_s", Json::Num(rep.wall_s)),
        ("req_per_s", Json::Num(rep.req_per_s())),
        ("tok_per_s", Json::Num(rep.tok_per_s())),
        ("ttft_ms_p50", Json::Num(rep.ttft_pctile(0.50))),
        ("ttft_ms_p95", Json::Num(rep.ttft_pctile(0.95))),
    ]))
}

/// Kill 1 of 2 workers and time the healing: how long until the first
/// post-kill request completes through the router (failover latency), and
/// until the prober has tripped the dead worker's breaker (detection).
fn recovery_point(dir: &str, prompt: &str, max_new: usize) -> anyhow::Result<Json> {
    let rcfg = RouterConfig {
        breaker: BreakerConfig { failure_threshold: 2, open_ticks: 10 },
        health: HealthConfig { tick: Duration::from_millis(25), probe_every: 2 },
        ..Default::default()
    };
    let mut fleet = spawn_fleet(dir, 2, rcfg)?;
    let mut c = Client::connect(&fleet.router_addr)?;
    // warm both the fleet and the client connection
    c.generate(&WireRequest::new(1, prompt, max_new))?;

    let (_, stop) = fleet.workers.remove(0);
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let killed_at = Instant::now();

    let mut failover_ms = None;
    for id in 2..64u64 {
        if let recalkv::server::GenOutcome::Done { events } = c.generate(&WireRequest::new(
            id,
            prompt,
            max_new,
        ))? {
            if matches!(events.last().map(|(ev, _)| ev), Some(WireEvent::Finished(_))) {
                failover_ms = Some(killed_at.elapsed().as_secs_f64() * 1e3);
                break;
            }
        }
    }
    let failover_ms = failover_ms
        .ok_or_else(|| anyhow::anyhow!("no request completed after the worker kill"))?;

    let mut detection_ms = None;
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        let j = c.metrics()?;
        let healthy =
            j.req("router").req("workers_healthy").as_f64().unwrap_or(f64::NAN);
        if healthy == 1.0 {
            detection_ms = Some(killed_at.elapsed().as_secs_f64() * 1e3);
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let detection_ms = detection_ms
        .ok_or_else(|| anyhow::anyhow!("the prober never tripped the dead worker's breaker"))?;
    println!(
        "recovery after kill 1/2: first completed request {failover_ms:.1}ms, \
         breaker open {detection_ms:.1}ms"
    );
    drop(c);
    fleet.shutdown()?;
    Ok(Json::obj(vec![
        ("failover_first_completion_ms", Json::Num(failover_ms)),
        ("breaker_detection_ms", Json::Num(detection_ms)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &["quick"]);
    let out_path = args.opt_or("out", "BENCH_router.json").to_string();
    let quick = args.has("quick");
    let budget = Duration::from_millis(if quick { 150 } else { 400 });
    let reqs = args.usize_or("requests", if quick { 2 } else { 6 });
    let max_new = args.usize_or("max-new", if quick { 8 } else { 16 });
    let worker_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };

    let micro = router_microbench(budget);

    let dir = args.opt_or("artifacts", "artifacts").to_string();
    let (fanout, recovery) = match Manifest::load(&dir) {
        Ok(_) => {
            let prompts: Vec<String> = recalkv::eval::tasks::gen_long("needle", 42, 8, 200)
                .into_iter()
                .map(|inst| inst.prompt)
                .collect();
            let mut table = Table::new(
                "Router fan-out, localhost loopback",
                &["workers", "req/s", "tok/s", "ttft p50/p95 ms"],
            );
            let mut rows = Vec::new();
            for &n in worker_counts {
                // clients scale with the fleet so each point keeps every
                // worker busy rather than measuring an idle tail
                let clients = (n * 2).max(2);
                let row = fanout_point(&dir, n, clients, reqs, &prompts, max_new)?;
                table.row(vec![
                    n.to_string(),
                    format!("{:.1}", row.req("req_per_s").as_f64().unwrap_or(0.0)),
                    format!("{:.1}", row.req("tok_per_s").as_f64().unwrap_or(0.0)),
                    format!(
                        "{:.1}/{:.1}",
                        row.req("ttft_ms_p50").as_f64().unwrap_or(0.0),
                        row.req("ttft_ms_p95").as_f64().unwrap_or(0.0)
                    ),
                ]);
                rows.push(row);
            }
            table.print();
            let recovery = recovery_point(&dir, "the dog barks . the cat sleeps . ", max_new)?;
            (Json::Arr(rows), recovery)
        }
        Err(_) => {
            println!("[skip] artifacts/ not built — router micro-paths only");
            (Json::Null, Json::Null)
        }
    };

    let report = Json::obj(vec![
        ("bench", Json::Str("router_fanout".into())),
        ("micro", micro),
        ("fanout", fanout),
        ("recovery", recovery),
    ]);
    std::fs::write(&out_path, report.to_string())?;
    println!("[report saved to {out_path}]");
    Ok(())
}
