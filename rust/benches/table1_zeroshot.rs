//! Regenerates paper Table 1: perplexity (3 splits) + six zero-shot tasks,
//! Palu vs ReCalKV at 50/60/70(/90)% on both models.
//!
//! Bench defaults are CI-sized; the full-size run is recorded in
//! artifacts/tables/e2e_run.txt (via `repro tables`). Override with e.g.
//!   cargo bench --bench table1_zeroshot -- --mc 32 --ppl-tokens 4096

use recalkv::artifacts::Manifest;
use recalkv::eval::report::{self, EvalSizes};
use recalkv::runtime::Runtime;
use recalkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &[]);
    let man = Manifest::load(args.opt_or("artifacts", "artifacts"))?;
    let mut sizes = EvalSizes::from_manifest(&man);
    sizes.ppl_tokens = args.usize_or("ppl-tokens", 2048);
    sizes.mc_per_task = args.usize_or("mc", 16);
    let models: Vec<String> = args
        .opt_or("models", "tiny-mha,tiny-gqa")
        .split(',')
        .map(String::from)
        .collect();
    let refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
    let rt = Runtime::cpu()?;
    let t = report::table1(&rt, &man, &refs, &sizes)?;
    t.print();
    t.save_tsv("artifacts/tables/table1.tsv");
    Ok(())
}
