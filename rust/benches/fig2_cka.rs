//! Regenerates paper Figure 2 (CKA similarity before/after head reordering,
//! ASCII heatmaps + within-group similarity) and the §1 Fisher-information
//! analysis figure.
//!
//!   cargo bench --bench fig2_cka

use recalkv::artifacts::Manifest;
use recalkv::eval::report;
use recalkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &[]);
    let man = Manifest::load(args.opt_or("artifacts", "artifacts"))?;
    let model = args.opt_or("model", "tiny-mha");
    let fig = report::figure2(&man, model)?;
    println!("{fig}");
    std::fs::create_dir_all("artifacts/tables").ok();
    std::fs::write("artifacts/tables/figure2.txt", &fig)?;

    // within-group similarity deltas recorded at compress time
    let m = man.model(model)?;
    for (vname, v) in &m.variants {
        if v.method == "recal" {
            println!("{vname}: kv_perms = {:?}", v.kv_perms);
        }
    }

    let t = report::fisher_figure(&man, model)?;
    t.print();
    t.save_tsv("artifacts/tables/fisher.tsv");
    Ok(())
}
