//! Regenerates paper Table 3: the HSR × offline-calibration ablation at a
//! fixed 80% compression ratio on tiny-mha.
//!
//! Bench defaults are CI-sized; the full-size run is recorded in
//! artifacts/tables/e2e_run.txt (via `repro tables`). Override with e.g.
//!   cargo bench --bench table3_ablation

use recalkv::artifacts::Manifest;
use recalkv::eval::report::{self, EvalSizes};
use recalkv::runtime::Runtime;
use recalkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &[]);
    let man = Manifest::load(args.opt_or("artifacts", "artifacts"))?;
    let mut sizes = EvalSizes::from_manifest(&man);
    sizes.ppl_tokens = args.usize_or("ppl-tokens", 2048);
    sizes.mc_per_task = args.usize_or("mc", 16);
    sizes.long_per_task = args.usize_or("long", 4);
    let rt = Runtime::cpu()?;
    let t = report::table3(&rt, &man, &sizes)?;
    t.print();
    t.save_tsv("artifacts/tables/table3.tsv");
    Ok(())
}
