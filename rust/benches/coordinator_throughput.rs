//! L3 hot-path benchmark: end-to-end decode throughput through the engine,
//! batching-policy ablation, and the staging (gather + dequant) micro-path.
//! This is the §Perf harness for the coordinator layer.
//!
//!   cargo bench --bench coordinator_throughput -- --requests 16
//!
//! `stage full` counts the one O(S·w) gather per admitted request; `stage
//! incr` counts the per-token O(w) tail writes of the incremental decode
//! path (see rust/benches/decode_staging.rs for the isolated comparison).

use recalkv::artifacts::Manifest;
use recalkv::coordinator::{Engine, EngineConfig, GenRequest};
use recalkv::kvcache::{CacheConfig, KvCache};
use recalkv::quant::QuantKind;
use recalkv::runtime::Runtime;
use recalkv::util::bench::{bench, Table};
use recalkv::util::cli::Args;
use recalkv::util::rng::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &[]);
    staging_microbench();

    let man = match Manifest::load(args.opt_or("artifacts", "artifacts")) {
        Ok(m) => m,
        Err(_) => {
            println!("[skip] artifacts/ not built — staging microbench only");
            return Ok(());
        }
    };
    let rt = Runtime::cpu()?;
    let model = man.model("tiny-mha")?;
    let n_req = args.usize_or("requests", 8);
    let max_new = args.usize_or("max-new", 12);

    let mut t = Table::new(
        "Coordinator throughput (end-to-end serving)",
        &[
            "variant", "quant", "decode ms/step", "decode tok/s", "prefill ms",
            "stage full ms", "stage incr ms", "ttft ms", "occupancy",
        ],
    );
    for (vname, quant) in [
        ("full", QuantKind::F32),
        ("recal@50", QuantKind::F32),
        ("recal@50", QuantKind::Int4),
        ("recal@70", QuantKind::F32),
    ] {
        let variant = model.variant(vname)?;
        let mut engine = Engine::new(&rt, model, variant,
                                     EngineConfig { quant, ..Default::default() })?;
        let insts = recalkv::eval::tasks::gen_long("needle", 42, n_req, 200);
        for (i, inst) in insts.iter().enumerate() {
            let prompt = recalkv::coordinator::tokenizer::encode(&inst.prompt);
            engine
                .submit(GenRequest::new(i as u64, prompt, max_new))
                .expect("unbounded queue");
        }
        let results = engine.run_to_completion()?;
        if let Some(r) = results.iter().find(|r| r.error.is_some()) {
            anyhow::bail!(
                "{vname} {quant:?}: request {} failed ({}) — refusing to record a \
                 partially-failed run",
                r.id,
                r.error.as_deref().unwrap_or("")
            );
        }
        let m = &engine.metrics;
        t.row(vec![
            vname.into(),
            format!("{quant:?}"),
            format!("{:.2}", m.decode_time.as_secs_f64() * 1e3 / m.decode_calls.max(1) as f64),
            format!("{:.1}", m.decode_tokens_per_s()),
            format!("{:.1}", m.prefill_time.as_secs_f64() * 1e3 / m.prefill_calls.max(1) as f64),
            format!("{:.2}", m.stage_full_time.as_secs_f64() * 1e3),
            format!("{:.2}", m.stage_incr_time.as_secs_f64() * 1e3),
            format!("{:.1}", m.mean_ttft_ms()),
            format!("{:.2}", m.mean_batch_occupancy()),
        ]);
        t.print_last();
    }
    t.print();
    t.save_tsv("artifacts/tables/coordinator_throughput.tsv");
    Ok(())
}

/// Cache staging (gather + dequant) without XLA — the pure-rust hot loop.
/// Contrasts the old per-step full gather with the incremental tail write
/// (append one row + stage it) at the engine's default shapes.
fn staging_microbench() {
    let mut rng = Rng::new(3);
    for (quant, label) in [(QuantKind::F32, "f32"), (QuantKind::Int4, "int4")] {
        let widths = vec![(96usize, 128usize); 4];
        let mut cache = KvCache::new(CacheConfig {
            n_layers: 4,
            widths,
            cache_len: 512,
            tokens_per_block: 32,
            capacity_tokens: 1 << 15,
            quant,
            signs_seed: 7,
        });
        let seq = cache.new_seq();
        let k: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
        let v: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
        for _ in 0..400 {
            let rows: Vec<(&[f32], &[f32])> = (0..4).map(|_| (&k[..], &v[..])).collect();
            cache.append(seq, &rows).unwrap();
        }
        let mut out = vec![0.0f32; 512 * 128];
        bench(&format!("stage {label} full 400tok x4L"), Duration::from_millis(600), || {
            for l in 0..4 {
                cache.stage(seq, l, 1, &mut out, 512).unwrap();
            }
        });
        bench(&format!("stage {label} incr 1tok x4L"), Duration::from_millis(600), || {
            for l in 0..4 {
                cache.stage_rows(seq, l, 1, 399, 400, &mut out[..128]).unwrap();
            }
        });
    }
}
