//! Wire-serving benchmark: the TCP server on localhost loopback under
//! concurrent-client load, plus runtime-free protocol micro-paths.
//!
//! Emits `BENCH_server.json` so successive PRs have a network-perf
//! trajectory: requests/s and streamed tok/s end-to-end through the wire,
//! client-observed TTFT and inter-token-event latency p50/p95, at 1/4/16
//! concurrent connections (1/4 with --quick), plus frame encode/decode
//! throughput. A second section drives a zipfian shared-prefix workload
//! through the latent prefix cache (`--prefix-pages` sizes the arena) and
//! records cold-vs-warm TTFT percentiles and the trie hit rate. Both
//! serving sections need artifacts/ (skipped gracefully without them); the
//! protocol section always runs.
//!
//!   cargo bench --bench server_wire -- --out ../BENCH_server.json

use recalkv::artifacts::Manifest;
use recalkv::coordinator::{Coordinator, Engine, EngineConfig};
use recalkv::server::{
    run_load, Client, Server, ServerConfig, ServerFrame, WireEvent, WireResult,
};
use recalkv::util::bench::{bench, Table};
use recalkv::util::cli::Args;
use recalkv::util::json::Json;
use std::time::Duration;

/// Frame encode/decode throughput (runtime-free): the per-token cost the
/// wire adds over the in-process stream.
fn protocol_microbench(budget: Duration) -> Json {
    let token_frame = ServerFrame::Event(WireEvent::Token {
        id: 12345,
        token: 104,
        text_delta: "h".into(),
        logprob: -1.2503217828,
    });
    let enc = bench("token frame encode", budget, || {
        std::hint::black_box(token_frame.encode());
    });
    let line = token_frame.encode();
    let dec = bench("token frame decode", budget, || {
        std::hint::black_box(ServerFrame::decode(&line).unwrap());
    });
    let result_frame = ServerFrame::Event(WireEvent::Finished(WireResult {
        id: 12345,
        tokens: (0..64).collect(),
        text: "x".repeat(64),
        forced_logprob: 0.0,
        forced_count: 0,
        prompt_len: 128,
        ttft_ms: 5.25,
        total_ms: 90.5,
        queue_wait_ms: 0.5,
        reason: recalkv::coordinator::FinishReason::Completed,
        error: None,
    }));
    let line_r = result_frame.encode();
    let dec_r = bench("terminal frame decode", budget, || {
        std::hint::black_box(ServerFrame::decode(&line_r).unwrap());
    });
    Json::obj(vec![
        ("token_frame_bytes", Json::Num(line.len() as f64)),
        ("token_encode_ns", Json::Num(enc.median_ns)),
        ("token_decode_ns", Json::Num(dec.median_ns)),
        ("token_frames_per_s", Json::Num(dec.throughput(1.0))),
        ("terminal_frame_bytes", Json::Num(line_r.len() as f64)),
        ("terminal_decode_ns", Json::Num(dec_r.median_ns)),
    ])
}

/// One loopback scaling point: `clients` concurrent connections, each
/// streaming `reqs` requests sequentially.
fn loopback_point(
    addr: &str,
    clients: usize,
    reqs: usize,
    prompts: &[String],
    max_new: usize,
) -> anyhow::Result<Json> {
    let rep = run_load(addr, clients, reqs, prompts, max_new)?;
    println!(
        "{:>2} clients: {:>6.1} req/s {:>7.1} tok/s | ttft p50/p95 {:>6.1}/{:>6.1}ms | \
         token gap p50/p95 {:>5.2}/{:>5.2}ms | {} ok {} rejected {} failed",
        clients,
        rep.req_per_s(),
        rep.tok_per_s(),
        rep.ttft_pctile(0.50),
        rep.ttft_pctile(0.95),
        rep.event_gap_pctile(0.50),
        rep.event_gap_pctile(0.95),
        rep.completed,
        rep.rejected,
        rep.failed,
    );
    Ok(Json::obj(vec![
        ("clients", Json::Num(clients as f64)),
        ("requests", Json::Num(rep.requests as f64)),
        ("completed", Json::Num(rep.completed as f64)),
        ("rejected", Json::Num(rep.rejected as f64)),
        ("failed", Json::Num(rep.failed as f64)),
        ("wall_s", Json::Num(rep.wall_s)),
        ("req_per_s", Json::Num(rep.req_per_s())),
        ("tok_per_s", Json::Num(rep.tok_per_s())),
        ("ttft_ms_p50", Json::Num(rep.ttft_pctile(0.50))),
        ("ttft_ms_p95", Json::Num(rep.ttft_pctile(0.95))),
        ("token_gap_ms_p50", Json::Num(rep.event_gap_pctile(0.50))),
        ("token_gap_ms_p95", Json::Num(rep.event_gap_pctile(0.95))),
    ]))
}

/// Zipfian shared-prefix workload through the prefix cache: requests draw
/// their prompt from a small family set with zipf(1) popularity (weight
/// 1/rank), expanded into a fixed schedule and deterministically shuffled.
/// Pass 1 runs against an empty trie (cold — the first occurrence of each
/// family seeds it), pass 2 replays the identical schedule against the
/// populated trie (warm). Records client-observed TTFT p50/p95 per pass
/// plus each pass's hit rate off the worker's own counters.
fn prefix_zipf_bench(
    dir: String,
    prefix_pages: usize,
    n_reqs: usize,
    max_new: usize,
) -> anyhow::Result<Json> {
    use recalkv::server::{GenOutcome, WireRequest};
    use recalkv::util::rng::Rng;
    use std::time::Instant;

    let coord = Coordinator::spawn(move || {
        let man = Manifest::load(&dir)?;
        let rt = recalkv::runtime::Runtime::cpu()?;
        let model = man.model("tiny-mha")?;
        Engine::new(
            &rt,
            model,
            model.variant("recal@50")?,
            // 8-token pages: the ~40-token family prompts span several full
            // (shareable) pages, where the default 32-token pages would
            // leave sharing marginal.
            EngineConfig {
                prefix_cache_pages: prefix_pages,
                tokens_per_block: 8,
                ..Default::default()
            },
        )
    });
    let server = Server::bind("127.0.0.1:0", coord.handle(), ServerConfig::default())?;
    let addr = server.local_addr()?.to_string();
    let worker = std::thread::spawn(move || server.run());

    let families: Vec<String> = recalkv::eval::tasks::gen_long("needle", 7, 8, 200)
        .into_iter()
        .map(|inst| inst.prompt)
        .collect();
    let weight_sum: f64 = (0..families.len()).map(|r| 1.0 / (r + 1) as f64).sum();
    let mut schedule: Vec<usize> = Vec::new();
    for rank in 0..families.len() {
        let share = (1.0 / (rank + 1) as f64) / weight_sum;
        let count = ((n_reqs as f64 * share).round() as usize).max(1);
        schedule.extend(std::iter::repeat(rank).take(count));
    }
    let mut rng = Rng::new(42);
    rng.shuffle(&mut schedule);

    let pass = |label: &str| -> anyhow::Result<(f64, f64)> {
        let mut c = Client::connect(&addr)?;
        let mut ttfts: Vec<f64> = Vec::new();
        for (i, &fam) in schedule.iter().enumerate() {
            let t0 = Instant::now();
            let req = WireRequest::new(i as u64 + 1, families[fam].clone(), max_new);
            match c.generate(&req)? {
                GenOutcome::Done { events } => {
                    let first = events
                        .iter()
                        .find(|(ev, _)| matches!(ev, WireEvent::Token { .. }))
                        .map(|(_, at)| (*at - t0).as_secs_f64() * 1e3);
                    ttfts.push(first.unwrap_or(0.0));
                }
                GenOutcome::Rejected(e) => anyhow::bail!("{label}: request rejected: {e:?}"),
            }
        }
        ttfts.sort_by(f64::total_cmp);
        let pct = |p: f64| ttfts[((ttfts.len() - 1) as f64 * p) as usize];
        Ok((pct(0.50), pct(0.95)))
    };

    let (cold_p50, cold_p95) = pass("cold")?;
    let mut obs = Client::connect(&addr)?;
    let mid = obs.metrics()?;
    let (warm_p50, warm_p95) = pass("warm")?;
    let fin = obs.metrics()?;
    let m = |j: &Json, k: &str| j.req("metrics").req(k).as_f64().unwrap_or(0.0);
    let rate = |h: f64, mi: f64| if h + mi > 0.0 { h / (h + mi) } else { 0.0 };
    let cold_rate = rate(m(&mid, "prefix_hits"), m(&mid, "prefix_misses"));
    let warm_rate = rate(
        m(&fin, "prefix_hits") - m(&mid, "prefix_hits"),
        m(&fin, "prefix_misses") - m(&mid, "prefix_misses"),
    );
    let pages_held = fin.req("cache").req("prefix_pages_held").as_f64().unwrap_or(0.0);
    println!(
        "prefix zipf ({} families, {} reqs/pass, {prefix_pages} pages): \
         cold ttft p50/p95 {cold_p50:.1}/{cold_p95:.1}ms (hit rate {:.0}%) | \
         warm {warm_p50:.1}/{warm_p95:.1}ms (hit rate {:.0}%) | {pages_held:.0} pages held",
        families.len(),
        schedule.len(),
        cold_rate * 100.0,
        warm_rate * 100.0,
    );
    Client::connect(&addr)?.shutdown_server()?;
    worker.join().expect("server thread panicked")?;
    println!("{}", coord.shutdown()?);
    Ok(Json::obj(vec![
        ("families", Json::Num(families.len() as f64)),
        ("requests_per_pass", Json::Num(schedule.len() as f64)),
        ("prefix_pages", Json::Num(prefix_pages as f64)),
        ("cold_ttft_ms_p50", Json::Num(cold_p50)),
        ("cold_ttft_ms_p95", Json::Num(cold_p95)),
        ("warm_ttft_ms_p50", Json::Num(warm_p50)),
        ("warm_ttft_ms_p95", Json::Num(warm_p95)),
        ("cold_hit_rate", Json::Num(cold_rate)),
        ("warm_hit_rate", Json::Num(warm_rate)),
        ("prefix_pages_held", Json::Num(pages_held)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &["quick"]);
    let out_path = args.opt_or("out", "BENCH_server.json").to_string();
    let quick = args.has("quick");
    let budget = Duration::from_millis(if quick { 150 } else { 400 });
    let reqs = args.usize_or("requests", if quick { 2 } else { 6 });
    let max_new = args.usize_or("max-new", if quick { 8 } else { 16 });
    let client_counts: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };

    let protocol = protocol_microbench(budget);

    let dir = args.opt_or("artifacts", "artifacts").to_string();
    let loopback = match Manifest::load(&dir) {
        Ok(_) => {
            let coord = Coordinator::spawn(move || {
                let man = Manifest::load(&dir)?;
                let rt = recalkv::runtime::Runtime::cpu()?;
                let model = man.model("tiny-mha")?;
                Engine::new(&rt, model, model.variant("recal@50")?, EngineConfig::default())
            });
            let server =
                Server::bind("127.0.0.1:0", coord.handle(), ServerConfig::default())?;
            let addr = server.local_addr()?.to_string();
            let worker = std::thread::spawn(move || server.run());
            let prompts: Vec<String> = recalkv::eval::tasks::gen_long("needle", 42, 8, 200)
                .into_iter()
                .map(|inst| inst.prompt)
                .collect();

            let mut table = Table::new(
                "Wire serving, localhost loopback",
                &["clients", "req/s", "tok/s", "ttft p50/p95 ms", "gap p50/p95 ms"],
            );
            let mut rows = Vec::new();
            for &clients in client_counts {
                let row = loopback_point(&addr, clients, reqs, &prompts, max_new)?;
                table.row(vec![
                    clients.to_string(),
                    format!("{:.1}", row.req("req_per_s").as_f64().unwrap_or(0.0)),
                    format!("{:.1}", row.req("tok_per_s").as_f64().unwrap_or(0.0)),
                    format!(
                        "{:.1}/{:.1}",
                        row.req("ttft_ms_p50").as_f64().unwrap_or(0.0),
                        row.req("ttft_ms_p95").as_f64().unwrap_or(0.0)
                    ),
                    format!(
                        "{:.2}/{:.2}",
                        row.req("token_gap_ms_p50").as_f64().unwrap_or(0.0),
                        row.req("token_gap_ms_p95").as_f64().unwrap_or(0.0)
                    ),
                ]);
                rows.push(row);
            }
            table.print();
            Client::connect(&addr)?.shutdown_server()?;
            worker.join().expect("server thread panicked")?;
            println!("{}", coord.shutdown()?);
            Json::Arr(rows)
        }
        Err(_) => {
            println!("[skip] artifacts/ not built — protocol micro-paths only");
            Json::Null
        }
    };

    let prefix_dir = args.opt_or("artifacts", "artifacts").to_string();
    let prefix_zipf = match Manifest::load(&prefix_dir) {
        Ok(_) => {
            let prefix_pages = args.usize_or("prefix-pages", 512);
            let n_reqs = args.usize_or("prefix-requests", if quick { 16 } else { 48 });
            prefix_zipf_bench(prefix_dir, prefix_pages, n_reqs, max_new)?
        }
        Err(_) => Json::Null,
    };

    let report = Json::obj(vec![
        ("bench", Json::Str("server_wire".into())),
        ("protocol", protocol),
        ("loopback", loopback),
        ("prefix_zipf", prefix_zipf),
    ]);
    std::fs::write(&out_path, report.to_string())?;
    println!("[report saved to {out_path}]");
    Ok(())
}
