//! Regenerates paper Table 4: low-rank compression combined with per-token
//! int4/int3 cache quantization (randomized Hadamard), with perplexity
//! measured through the *serving* path so the quantized paged cache is the
//! thing under test.
//!
//! Bench defaults are CI-sized; the full-size run is recorded in
//! artifacts/tables/e2e_run.txt (via `repro tables`). Override with e.g.
//!   cargo bench --bench table4_quant -- --docs 8

use recalkv::artifacts::Manifest;
use recalkv::eval::report::{self, EvalSizes};
use recalkv::runtime::Runtime;
use recalkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &[]);
    let man = Manifest::load(args.opt_or("artifacts", "artifacts"))?;
    let mut sizes = EvalSizes::from_manifest(&man);
    sizes.engine_ppl_docs = args.usize_or("docs", 4);
    let rt = Runtime::cpu()?;
    let t = report::table4(&rt, &man, &sizes)?;
    t.print();
    t.save_tsv("artifacts/tables/table4.tsv");
    Ok(())
}
