//! Micro-benchmarks for the linalg substrate used by the offline mirror and
//! the quantized cache — GEMM (seed scalar loop vs packed register-tiled
//! kernel, scalar twin vs SIMD micro-kernel, single- and multi-threaded),
//! Jacobi SVD, Cholesky, Hadamard and per-token quant — plus the
//! end-to-end per-layer compression pipeline at 1/2/N pool threads (SIMD
//! on and forced off) against the seed-matmul single-thread baseline.
//!
//! Writes a machine-readable summary to `BENCH_linalg.json` (override with
//! `--out`) so successive PRs have an offline-compression perf trajectory
//! next to `BENCH_decode_staging.json`:
//!
//!   cargo bench --bench linalg_hotpath -- --out ../BENCH_linalg.json

use recalkv::compress::{compress_layer, LayerInputs, MethodCfg};
use recalkv::linalg::gemm::{gemm, set_force_naive};
use recalkv::linalg::hadamard::{forward, inverse, signs_from_seed};
use recalkv::linalg::{cholesky, svd, Matrix};
use recalkv::quant::{dequantize, quantize, QuantKind};
use recalkv::util::bench::{bench, BenchResult, Table};
use recalkv::util::cli::Args;
use recalkv::util::json::Json;
use recalkv::util::pool;
use recalkv::util::rng::Rng;
use recalkv::util::simd;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

fn rand_matrix(rng: &mut Rng, m: usize, n: usize) -> Matrix {
    Matrix::from_fn(m, n, |_, _| rng.normal())
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Synthetic layer shaped like the tiny-mha goldens (scaled by `--quick`).
struct LayerFixture {
    w_q: Matrix,
    w_k: Matrix,
    w_v: Matrix,
    w_o: Matrix,
    m: Matrix,
    x: Matrix,
    d: usize,
    n_heads: usize,
    d_head: usize,
}

fn layer_fixture(quick: bool) -> LayerFixture {
    let (d, n_heads, d_head, x_rows) = if quick { (128, 8, 16, 192) } else { (256, 8, 32, 320) };
    let mut rng = Rng::new(0xbe9c);
    let w_q = Matrix::from_fn(d, n_heads * d_head, |_, _| rng.normal() * 0.1);
    let w_k = Matrix::from_fn(d, n_heads * d_head, |_, _| rng.normal() * 0.1);
    let w_v = Matrix::from_fn(d, n_heads * d_head, |_, _| rng.normal() * 0.1);
    let w_o = Matrix::from_fn(n_heads * d_head, d, |_, _| rng.normal() * 0.1);
    let x = Matrix::from_fn(x_rows, d, |_, _| rng.normal());
    let m = x.gram();
    LayerFixture { w_q, w_k, w_v, w_o, m, x, d, n_heads, d_head }
}

/// Full `compress_layer` runs at a pinned thread count and SIMD policy;
/// returns the best wall seconds of `reps` runs (single samples are too
/// noisy to persist — the min discards scheduler and cold-cache outliers).
fn run_layer(fx: &LayerFixture, threads: usize, naive: bool, scalar: bool, reps: usize) -> f64 {
    pool::set_threads(threads);
    set_force_naive(naive);
    simd::set_force_scalar(scalar);
    let inp = LayerInputs {
        w_q: &fx.w_q,
        w_k: &fx.w_k,
        w_v: &fx.w_v,
        w_o: &fx.w_o,
        m: &fx.m,
        x_sample: &fx.x,
        n_heads: fx.n_heads,
        n_kv_heads: fx.n_heads,
        d_head: fx.d_head,
        group_size: 4,
        key_rank: fx.d_head * 2,
        value_rank: fx.d / 2,
    };
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let out = compress_layer(&inp, MethodCfg::from_name("recal").unwrap()).unwrap();
        best = best.min(t0.elapsed().as_secs_f64());
        std::hint::black_box(out.wo_fused.frob_sq());
    }
    pool::set_threads(0);
    set_force_naive(false);
    simd::set_force_scalar(false);
    best
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"), &["quick"]);
    let quick = args.has("quick");
    let out_path = args.opt_or("out", "BENCH_linalg.json").to_string();
    let budget = Duration::from_millis(if quick { 200 } else { 500 });
    let mut rng = Rng::new(5);
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let tier = simd::tier();
    println!("SIMD tier: {} (PALLAS_SIMD / util::simd dispatch)", tier.name());

    // --- GEMM: seed loop vs tiled kernel, scalar twin vs SIMD ------------
    let sizes: Vec<usize> = if quick { vec![128, 256] } else { vec![128, 256, 512] };
    let mut gemm_rows = Vec::new();
    let nt_header = format!("simd {avail}t");
    let mut gemm_table = Table::new(
        "GEMM GFLOP/s (f32, square)",
        &["n", "seed naive", "scalar 1t", "simd 1t", nt_header.as_str(), "simd/scalar 1t"],
    );
    for &n in &sizes {
        let a = rand_matrix(&mut rng, n, n);
        let b = rand_matrix(&mut rng, n, n);
        let flops = 2.0 * (n as f64).powi(3);
        set_force_naive(true);
        simd::set_force_scalar(true);
        let naive = bench(&format!("matmul naive {n}"), budget, || {
            std::hint::black_box(a.matmul(&b));
        });
        set_force_naive(false);
        pool::set_threads(1);
        let scalar1 = bench(&format!("matmul tiled-scalar {n} 1t"), budget, || {
            std::hint::black_box(gemm(&a, &b));
        });
        simd::set_force_scalar(false);
        let simd1 = bench(&format!("matmul tiled-simd {n} 1t"), budget, || {
            std::hint::black_box(gemm(&a, &b));
        });
        pool::set_threads(0);
        let simd_n = bench(&format!("matmul tiled-simd {n} {avail}t"), budget, || {
            std::hint::black_box(gemm(&a, &b));
        });
        let gf = |r: &BenchResult| flops / r.median_ns;
        gemm_table.row(vec![
            n.to_string(),
            format!("{:.2}", gf(&naive)),
            format!("{:.2}", gf(&scalar1)),
            format!("{:.2}", gf(&simd1)),
            format!("{:.2}", gf(&simd_n)),
            format!("{:.1}x", scalar1.median_ns / simd1.median_ns),
        ]);
        gemm_table.print_last();
        gemm_rows.push(obj(vec![
            ("n", Json::Num(n as f64)),
            ("naive_gflops", Json::Num(gf(&naive))),
            ("tiled_scalar_1t_gflops", Json::Num(gf(&scalar1))),
            ("tiled_simd_1t_gflops", Json::Num(gf(&simd1))),
            ("tiled_simd_nt_gflops", Json::Num(gf(&simd_n))),
            ("simd_vs_scalar_1t", Json::Num(scalar1.median_ns / simd1.median_ns)),
            ("tiled_scalar_vs_naive_1t", Json::Num(naive.median_ns / scalar1.median_ns)),
        ]));
    }
    gemm_table.print();

    // --- FWHT + int4 dequant: scalar twins vs SIMD, GB/s -----------------
    let hn = 128usize;
    let hrows = 512usize;
    let signs = signs_from_seed(9, hn);
    let mut x: Vec<f32> = (0..hrows * hn).map(|_| rng.normal()).collect();
    // Memory traffic per direction: log2(b) butterfly stages (each reads
    // and writes every element) plus the sign-multiply and normalization
    // passes (read+write each); forward+inverse doubles it.
    let stages = recalkv::linalg::hadamard::block_size(hn).trailing_zeros() as usize;
    let fwht_bytes = (hrows * hn * 4) as f64 * 2.0 * (stages as f64 * 2.0 + 4.0);
    simd::set_force_scalar(true);
    let fwht_s = bench(&format!("hadamard fwd+inv {hrows}x{hn} scalar"), budget, || {
        forward(&mut x, &signs);
        inverse(&mut x, &signs);
    });
    simd::set_force_scalar(false);
    let fwht_v = bench(&format!("hadamard fwd+inv {hrows}x{hn} simd"), budget, || {
        forward(&mut x, &signs);
        inverse(&mut x, &signs);
    });
    let gbps = |bytes: f64, r: &BenchResult| bytes / r.median_ns; // B/ns == GB/s
    println!(
        "  -> fwht {:.2} GB/s scalar, {:.2} GB/s {} ({:.1}x)",
        gbps(fwht_bytes, &fwht_s),
        gbps(fwht_bytes, &fwht_v),
        tier.name(),
        fwht_s.median_ns / fwht_v.median_ns
    );

    let row: Vec<f32> = (0..hn).map(|_| rng.normal()).collect();
    let mut out = vec![0.0f32; hn];
    let q4 = quantize(&row, &signs, QuantKind::Int4);
    // Full staging dequant (nibble decode + scale + inverse Hadamard) —
    // the decode-hot op itself. The FWHT dominates at this width, so the
    // isolated nibble decode is measured separately below.
    let dq_bytes = (hn * 4) as f64; // staged f32 output per token row
    simd::set_force_scalar(true);
    let dq_s = bench(&format!("dequant int4 row {hn}-dim scalar"), budget, || {
        dequantize(&q4, &signs, &mut out);
    });
    simd::set_force_scalar(false);
    let dq_v = bench(&format!("dequant int4 row {hn}-dim simd"), budget, || {
        dequantize(&q4, &signs, &mut out);
    });
    println!(
        "  -> int4 row dequant {:.2} GB/s scalar, {:.2} GB/s {} ({:.1}x), {:.1} Mtok/s",
        gbps(dq_bytes, &dq_s),
        gbps(dq_bytes, &dq_v),
        tier.name(),
        dq_s.median_ns / dq_v.median_ns,
        1.0 / (dq_v.median_ns / 1e3)
    );
    // Isolated nibble decode (no Hadamard) so decode16 regressions can't
    // hide behind the butterfly kernel.
    let mut codes = vec![0i32; hn];
    simd::set_force_scalar(true);
    let up_s = bench(&format!("unpack int4 {hn}-dim scalar"), budget, || {
        recalkv::quant::unpack_int4_into(&q4.packed, &mut codes);
        std::hint::black_box(codes[0]);
    });
    simd::set_force_scalar(false);
    let up_v = bench(&format!("unpack int4 {hn}-dim simd"), budget, || {
        recalkv::quant::unpack_int4_into(&q4.packed, &mut codes);
        std::hint::black_box(codes[0]);
    });
    println!(
        "  -> int4 unpack {:.2} GB/s scalar, {:.2} GB/s {} ({:.1}x)",
        gbps(dq_bytes, &up_s),
        gbps(dq_bytes, &up_v),
        tier.name(),
        up_s.median_ns / up_v.median_ns
    );

    // --- end-to-end per-layer pipeline at 1/2/N threads, SIMD on/off -----
    let fx = layer_fixture(quick);
    println!(
        "\nper-layer pipeline d={} h={} dh={} x_rows={} (recal: CKA + HSR + \
         whitened grouped SVD + calibration + fusion)",
        fx.d, fx.n_heads, fx.d_head, fx.x.rows
    );
    let reps = if quick { 2 } else { 3 };
    let baseline = run_layer(&fx, 1, true, true, reps);
    println!("  seed baseline (naive matmul, scalar, 1 thread): {baseline:.2}s (best of {reps})");
    let mut counts: Vec<usize> = vec![1, 2, avail];
    counts.sort_unstable();
    counts.dedup();
    let mut pipe_rows = Vec::new();
    let mut pipe_table = Table::new(
        "Per-layer compression wall time (tiled GEMM + work pool + SIMD)",
        &["threads", "scalar wall", "simd wall", "simd speedup", "speedup vs seed"],
    );
    for &t in &counts {
        let dt_scalar = run_layer(&fx, t, false, true, reps);
        let dt = run_layer(&fx, t, false, false, reps);
        let speedup = baseline / dt.max(1e-12);
        pipe_table.row(vec![
            t.to_string(),
            format!("{dt_scalar:.2}s"),
            format!("{dt:.2}s"),
            format!("{:.1}x", dt_scalar / dt.max(1e-12)),
            format!("{speedup:.1}x"),
        ]);
        pipe_table.print_last();
        pipe_rows.push(obj(vec![
            ("threads", Json::Num(t as f64)),
            ("wall_scalar_s", Json::Num(dt_scalar)),
            ("wall_s", Json::Num(dt)),
            ("simd_speedup", Json::Num(dt_scalar / dt.max(1e-12))),
            ("speedup_vs_seed", Json::Num(speedup)),
        ]));
    }
    pipe_table.print();

    // --- the seed's remaining hot kernels ---------------------------------
    let w = rand_matrix(&mut rng, 256, 128);
    bench("jacobi svd 256x128", Duration::from_secs(3), || {
        std::hint::black_box(svd(&w));
    });

    let g = rand_matrix(&mut rng, 300, 256).gram().add(&Matrix::eye(256).scale(0.5));
    bench("cholesky 256", budget, || {
        std::hint::black_box(cholesky(&g).unwrap());
    });

    for kind in [QuantKind::Int4, QuantKind::Int3] {
        let r = bench(&format!("quant+dequant {kind:?} {hn}-dim"), budget, || {
            let q = quantize(&row, &signs, kind);
            dequantize(&q, &signs, &mut out);
        });
        println!("  -> {:.1} Mtok/s", 1.0 / (r.median_ns / 1e3));
    }

    let report = obj(vec![
        ("bench", Json::Str("linalg_hotpath".into())),
        ("threads_available", Json::Num(avail as f64)),
        ("simd_tier", Json::Str(tier.name().into())),
        (
            "pipeline_shape",
            obj(vec![
                ("d", Json::Num(fx.d as f64)),
                ("n_heads", Json::Num(fx.n_heads as f64)),
                ("d_head", Json::Num(fx.d_head as f64)),
                ("x_rows", Json::Num(fx.x.rows as f64)),
            ]),
        ),
        ("gemm", Json::Arr(gemm_rows)),
        (
            "fwht",
            obj(vec![
                ("rows", Json::Num(hrows as f64)),
                ("dim", Json::Num(hn as f64)),
                ("gbps_scalar", Json::Num(gbps(fwht_bytes, &fwht_s))),
                ("gbps_simd", Json::Num(gbps(fwht_bytes, &fwht_v))),
                ("simd_vs_scalar", Json::Num(fwht_s.median_ns / fwht_v.median_ns)),
            ]),
        ),
        (
            "dequant_int4_row",
            obj(vec![
                ("dim", Json::Num(hn as f64)),
                ("gbps_scalar", Json::Num(gbps(dq_bytes, &dq_s))),
                ("gbps_simd", Json::Num(gbps(dq_bytes, &dq_v))),
                ("simd_vs_scalar", Json::Num(dq_s.median_ns / dq_v.median_ns)),
            ]),
        ),
        (
            "unpack_int4",
            obj(vec![
                ("dim", Json::Num(hn as f64)),
                ("gbps_scalar", Json::Num(gbps(dq_bytes, &up_s))),
                ("gbps_simd", Json::Num(gbps(dq_bytes, &up_v))),
                ("simd_vs_scalar", Json::Num(up_s.median_ns / up_v.median_ns)),
            ]),
        ),
        ("pipeline_seed_baseline_s", Json::Num(baseline)),
        ("pipeline", Json::Arr(pipe_rows)),
    ]);
    std::fs::write(&out_path, report.to_string())?;
    println!("[report saved to {out_path}]");
    Ok(())
}
