//! Micro-benchmarks for the linalg substrate used by the offline mirror and
//! the quantized cache: matmul, Jacobi SVD, Cholesky, Hadamard transforms.

use recalkv::linalg::hadamard::{forward, inverse, signs_from_seed};
use recalkv::linalg::{cholesky, svd, Matrix};
use recalkv::quant::{dequantize, quantize, QuantKind};
use recalkv::util::bench::bench;
use recalkv::util::rng::Rng;
use std::time::Duration;

fn rand_matrix(rng: &mut Rng, m: usize, n: usize) -> Matrix {
    Matrix::from_fn(m, n, |_, _| rng.normal())
}

fn main() {
    let mut rng = Rng::new(5);
    let budget = Duration::from_millis(700);

    let a = rand_matrix(&mut rng, 256, 256);
    let b = rand_matrix(&mut rng, 256, 256);
    let r = bench("matmul 256x256x256", budget, || {
        std::hint::black_box(a.matmul(&b));
    });
    println!(
        "  -> {:.2} GFLOP/s",
        2.0 * 256f64.powi(3) / r.median_ns
    );

    let w = rand_matrix(&mut rng, 256, 128);
    bench("jacobi svd 256x128", Duration::from_secs(3), || {
        std::hint::black_box(svd(&w));
    });

    let g = rand_matrix(&mut rng, 300, 256).gram().add(&Matrix::eye(256).scale(0.5));
    bench("cholesky 256", budget, || {
        std::hint::black_box(cholesky(&g).unwrap());
    });

    let signs = signs_from_seed(9, 128);
    let mut x: Vec<f32> = (0..512 * 128).map(|_| rng.normal()).collect();
    let r = bench("hadamard fwd+inv 512x128", budget, || {
        forward(&mut x, &signs);
        inverse(&mut x, &signs);
    });
    println!(
        "  -> {:.1} Mtok/s (128-dim rows)",
        2.0 * 512.0 / (r.median_ns / 1e3)
    );

    let row: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
    let mut out = vec![0.0f32; 128];
    for kind in [QuantKind::Int4, QuantKind::Int3] {
        let r = bench(&format!("quant+dequant {kind:?} 128-dim"), budget, || {
            let q = quantize(&row, &signs, kind);
            dequantize(&q, &signs, &mut out);
        });
        println!("  -> {:.1} Mtok/s", 1.0 / (r.median_ns / 1e3));
    }
}
