"""L2: LLaMA-style decoder-only transformer in JAX, full + ReCalKV-compressed.

Three graph entry points per (model, variant), AOT-lowered by aot.py and
executed from rust:

  score(tokens)            -> logits [B,S,V]            (ppl + MC tasks)
  prefill(tokens, length)  -> per-layer caches + last logits
  decode(token, caches, length) -> logits + new cache entries (one step)

The *compressed* decode path calls the L1 Pallas kernels
(grouped_key_scores, latent_ctx) so they lower into the HLO the rust
coordinator executes on every step. score/prefill use the pure-jnp oracles
(identical math — asserted by python/tests/test_model.py) because pallas
interpret-mode lowering of long-sequence grids is wasteful at build time.

Weight layout (dict of f32 arrays, also the .rtz archive layout):
  embed [V,d]                         tied output head
  L{l}.ln1 / L{l}.ln2 [d]             RMSNorm gains
  L{l}.wq [d, h*dh]   L{l}.wk [d, kvh*dh]   L{l}.wv [d, kvh*dh]
  L{l}.wo [h*dh, d]
  L{l}.w1 / L{l}.w3 [d, ff]  L{l}.w2 [ff, d]   (SwiGLU)
  norm_f [d]

Compressed variants replace, per layer (built by compress/pipeline.py):
  L{l}.wq      -> columns permuted to the reordered q-head layout
  L{l}.wk/wv   -> L{l}.Lk [d, g*rk], L{l}.Rk [g, rk, s*dh], L{l}.Lv [d, rv]
  L{l}.wo      -> L{l}.wo_fused [h*rv, d]   (= blockwise R_v·W_o, reordered)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.grouped_key_attn import grouped_key_scores
from .kernels.latent_ctx import latent_ctx

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny-mha"
    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 8
    d_head: int = 32
    d_ff: int = 640
    max_seq: int = 1024
    rope_theta: float = 10000.0

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Per-model compression description (shapes only; factors live in params).

    group_size: kv-heads per key group (paper: 4 for h=32; we scale to 4 for
    kvh=8 MHA and 2 for kvh=4 GQA so g=2 groups in both).
    key_ranks[l]: per-group key rank of layer l.
    value_ranks[l]: value latent rank of layer l.
    kv_perms[l]: reordered kv-head order (position p holds original head
    kv_perms[l][p]); already folded into factor layout, kept for tests/eval.
    """
    method: str                 # "recal" | "palu" | ablation tags
    ratio: float                # target compression ratio (paper's RATIO)
    group_size: int
    key_ranks: Tuple[int, ...]
    value_ranks: Tuple[int, ...]
    kv_perms: Tuple[Tuple[int, ...], ...]

    def n_groups(self, cfg: ModelConfig) -> int:
        return cfg.n_kv_heads // self.group_size


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """LLaMA-style init: normal(0, 0.02), w2/wo scaled by 1/sqrt(2*L)."""
    rng = np.random.default_rng(seed)
    p: Dict[str, np.ndarray] = {}

    def w(shape, scale=0.02):
        return rng.standard_normal(shape, dtype=np.float32) * scale

    p["embed"] = w((cfg.vocab, cfg.d_model))
    out_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    for l in range(cfg.n_layers):
        p[f"L{l}.ln1"] = np.ones(cfg.d_model, np.float32)
        p[f"L{l}.ln2"] = np.ones(cfg.d_model, np.float32)
        p[f"L{l}.wq"] = w((cfg.d_model, cfg.q_dim))
        p[f"L{l}.wk"] = w((cfg.d_model, cfg.kv_dim))
        p[f"L{l}.wv"] = w((cfg.d_model, cfg.kv_dim))
        p[f"L{l}.wo"] = w((cfg.q_dim, cfg.d_model), out_scale)
        p[f"L{l}.w1"] = w((cfg.d_model, cfg.d_ff))
        p[f"L{l}.w3"] = w((cfg.d_model, cfg.d_ff))
        p[f"L{l}.w2"] = w((cfg.d_ff, cfg.d_model), out_scale)
    p["norm_f"] = np.ones(cfg.d_model, np.float32)
    return {k: jnp.asarray(v) for k, v in p.items()}


def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def rope_tables(cfg: ModelConfig, s_len: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    pos = jnp.arange(s_len, dtype=jnp.float32)
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, cfg.d_head, 2, dtype=jnp.float32) / cfg.d_head))
    ang = pos[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def _swiglu(p: Params, l: int, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p[f"L{l}.w1"]) * (x @ p[f"L{l}.w3"])) @ p[f"L{l}.w2"]


# ---------------------------------------------------------------------------
# Full (uncompressed) model.
# ---------------------------------------------------------------------------


def forward_full(p: Params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens [B,S] int32 -> logits [B,S,V]. Causal, teacher-forced."""
    b, s_len = tokens.shape
    x = p["embed"][tokens]
    cos, sin = rope_tables(cfg, s_len)
    causal = jnp.tril(jnp.ones((s_len, s_len), bool))
    rep = cfg.n_heads // cfg.n_kv_heads
    for l in range(cfg.n_layers):
        xn = rmsnorm(x, p[f"L{l}.ln1"])
        q = (xn @ p[f"L{l}.wq"]).reshape(b, s_len, cfg.n_heads, cfg.d_head)
        k = (xn @ p[f"L{l}.wk"]).reshape(b, s_len, cfg.n_kv_heads, cfg.d_head)
        v = (xn @ p[f"L{l}.wv"]).reshape(b, s_len, cfg.n_kv_heads, cfg.d_head)
        q = ref.rope_rotate(q, cos[None, :, None, :], sin[None, :, None, :])
        k = ref.rope_rotate(k, cos[None, :, None, :], sin[None, :, None, :])
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(jnp.float32(cfg.d_head))
        scores = jnp.where(causal[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(b, s_len, cfg.q_dim)
        x = x + ctx @ p[f"L{l}.wo"]
        x = x + _swiglu(p, l, rmsnorm(x, p[f"L{l}.ln2"]))
    x = rmsnorm(x, p["norm_f"])
    return x @ p["embed"].T


def loss_full(p: Params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross-entropy, mean over all positions."""
    logits = forward_full(p, cfg, tokens)[:, :-1]
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill_full(p: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                 length: jnp.ndarray):
    """Full-cache prefill: returns (logits_last [B,V], ks, vs) where ks/vs are
    per-layer [B,S,kvh,dh] (RoPE'd keys). Positions >= length are zeroed."""
    b, s_len = tokens.shape
    x = p["embed"][tokens]
    cos, sin = rope_tables(cfg, s_len)
    causal = jnp.tril(jnp.ones((s_len, s_len), bool))
    lmask = jnp.arange(s_len)[None] < length[:, None]          # [B,S]
    att_ok = causal[None] & lmask[:, None, :]                  # [B,T,S]
    rep = cfg.n_heads // cfg.n_kv_heads
    ks: List[jnp.ndarray] = []
    vs: List[jnp.ndarray] = []
    for l in range(cfg.n_layers):
        xn = rmsnorm(x, p[f"L{l}.ln1"])
        q = (xn @ p[f"L{l}.wq"]).reshape(b, s_len, cfg.n_heads, cfg.d_head)
        k = (xn @ p[f"L{l}.wk"]).reshape(b, s_len, cfg.n_kv_heads, cfg.d_head)
        v = (xn @ p[f"L{l}.wv"]).reshape(b, s_len, cfg.n_kv_heads, cfg.d_head)
        q = ref.rope_rotate(q, cos[None, :, None, :], sin[None, :, None, :])
        k = ref.rope_rotate(k, cos[None, :, None, :], sin[None, :, None, :])
        zero = lmask[..., None, None]
        ks.append(jnp.where(zero, k, 0.0))
        vs.append(jnp.where(zero, v, 0.0))
        kq = jnp.repeat(k, rep, axis=2)
        vq = jnp.repeat(v, rep, axis=2)
        scores = jnp.einsum("bthd,bshd->bhts", q, kq) / jnp.sqrt(jnp.float32(cfg.d_head))
        scores = jnp.where(att_ok[:, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhts,bshd->bthd", probs, vq).reshape(b, s_len, cfg.q_dim)
        x = x + ctx @ p[f"L{l}.wo"]
        x = x + _swiglu(p, l, rmsnorm(x, p[f"L{l}.ln2"]))
    x = rmsnorm(x, p["norm_f"])
    last = jnp.take_along_axis(x, (length - 1)[:, None, None], axis=1)[:, 0]
    return last @ p["embed"].T, ks, vs


def decode_full(p: Params, cfg: ModelConfig, token: jnp.ndarray,
                length: jnp.ndarray, ks: List[jnp.ndarray], vs: List[jnp.ndarray]):
    """One decode step against full caches.

    token [B] int32; length [B] = number of cached tokens (new token goes at
    position length). Returns (logits [B,V], new_k per layer [B,kvh,dh],
    new_v per layer).
    """
    b = token.shape[0]
    s_len = ks[0].shape[1]
    x = p["embed"][token]                                      # [B,d]
    cos_t, sin_t = rope_tables(cfg, cfg.max_seq)
    cos_p = cos_t[length]                                      # [B,dh/2]
    sin_p = sin_t[length]
    rep = cfg.n_heads // cfg.n_kv_heads
    valid = jnp.arange(s_len)[None] <= length[:, None]         # includes self
    new_ks: List[jnp.ndarray] = []
    new_vs: List[jnp.ndarray] = []
    for l in range(cfg.n_layers):
        xn = rmsnorm(x, p[f"L{l}.ln1"])
        q = (xn @ p[f"L{l}.wq"]).reshape(b, cfg.n_heads, cfg.d_head)
        k = (xn @ p[f"L{l}.wk"]).reshape(b, cfg.n_kv_heads, cfg.d_head)
        v = (xn @ p[f"L{l}.wv"]).reshape(b, cfg.n_kv_heads, cfg.d_head)
        q = ref.rope_rotate(q, cos_p[:, None, :], sin_p[:, None, :])
        k = ref.rope_rotate(k, cos_p[:, None, :], sin_p[:, None, :])
        new_ks.append(k)
        new_vs.append(v)
        # cache with the new entry written at position `length` per batch row
        kc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n[None], (i, 0, 0)))(
            ks[l], k, length)
        vc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n[None], (i, 0, 0)))(
            vs[l], v, length)
        kq = jnp.repeat(kc, rep, axis=2)
        vq = jnp.repeat(vc, rep, axis=2)
        scores = jnp.einsum("bhd,bshd->bhs", q, kq) / jnp.sqrt(jnp.float32(cfg.d_head))
        scores = jnp.where(valid[:, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhs,bshd->bhd", probs, vq).reshape(b, cfg.q_dim)
        x = x + ctx @ p[f"L{l}.wo"]
        x = x + _swiglu(p, l, rmsnorm(x, p[f"L{l}.ln2"]))
    x = rmsnorm(x, p["norm_f"])
    return x @ p["embed"].T, new_ks, new_vs


# ---------------------------------------------------------------------------
# Compressed model (ReCalKV / Palu variants; factors built offline).
# ---------------------------------------------------------------------------


def _compressed_attn_seq(p: Params, spec: CompressionSpec, cfg: ModelConfig,
                         l: int, xn: jnp.ndarray, cos, sin, att_ok):
    """Shared full-sequence compressed attention (score + prefill paths).

    Returns (attn_out [B,T,d], z_k [B,T,g,rk], z_v [B,T,rv]). Pure jnp —
    math identical to the pallas decode kernels (tested)."""
    b, s_len, _ = xn.shape
    g = spec.n_groups(cfg)
    rk = spec.key_ranks[l]
    rv = spec.value_ranks[l]
    z_k = (xn @ p[f"L{l}.Lk"]).reshape(b, s_len, g, rk)
    z_v = xn @ p[f"L{l}.Lv"]                                   # [B,T,rv]
    q = (xn @ p[f"L{l}.wq"]).reshape(b, s_len, cfg.n_heads, cfg.d_head)
    q = ref.rope_rotate(q, cos[None, :, None, :], sin[None, :, None, :])
    k = ref.ref_key_reconstruct(z_k, p[f"L{l}.Rk"], cos, sin)  # [B,T,kvh,dh]
    rep = cfg.n_heads // cfg.n_kv_heads
    kq = jnp.repeat(k, rep, axis=2)
    scores = jnp.einsum("bthd,bshd->bhts", q, kq) / jnp.sqrt(jnp.float32(cfg.d_head))
    scores = jnp.where(att_ok[:, None] if att_ok.ndim == 3 else att_ok[None, None],
                       scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bsr->bthr", probs, z_v)             # [B,T,h,rv]
    out = ctx.reshape(b, s_len, cfg.n_heads * rv) @ p[f"L{l}.wo_fused"]
    return out, z_k, z_v


def forward_compressed(p: Params, spec: CompressionSpec, cfg: ModelConfig,
                       tokens: jnp.ndarray) -> jnp.ndarray:
    """Compressed score path: tokens [B,S] -> logits [B,S,V]."""
    b, s_len = tokens.shape
    x = p["embed"][tokens]
    cos, sin = rope_tables(cfg, s_len)
    causal = jnp.tril(jnp.ones((s_len, s_len), bool))
    for l in range(cfg.n_layers):
        xn = rmsnorm(x, p[f"L{l}.ln1"])
        out, _, _ = _compressed_attn_seq(p, spec, cfg, l, xn, cos, sin, causal)
        x = x + out
        x = x + _swiglu(p, l, rmsnorm(x, p[f"L{l}.ln2"]))
    x = rmsnorm(x, p["norm_f"])
    return x @ p["embed"].T


def prefill_compressed(p: Params, spec: CompressionSpec, cfg: ModelConfig,
                       tokens: jnp.ndarray, length: jnp.ndarray):
    """Compressed prefill: returns (logits_last [B,V], zks, zvs); zks[l] is
    [B,S,g,rk_l], zvs[l] is [B,S,rv_l]; positions >= length zeroed."""
    b, s_len = tokens.shape
    x = p["embed"][tokens]
    cos, sin = rope_tables(cfg, s_len)
    causal = jnp.tril(jnp.ones((s_len, s_len), bool))
    lmask = jnp.arange(s_len)[None] < length[:, None]
    att_ok = causal[None] & lmask[:, None, :]
    zks: List[jnp.ndarray] = []
    zvs: List[jnp.ndarray] = []
    for l in range(cfg.n_layers):
        xn = rmsnorm(x, p[f"L{l}.ln1"])
        out, z_k, z_v = _compressed_attn_seq(p, spec, cfg, l, xn, cos, sin, att_ok)
        zks.append(jnp.where(lmask[..., None, None], z_k, 0.0))
        zvs.append(jnp.where(lmask[..., None], z_v, 0.0))
        x = x + out
        x = x + _swiglu(p, l, rmsnorm(x, p[f"L{l}.ln2"]))
    x = rmsnorm(x, p["norm_f"])
    last = jnp.take_along_axis(x, (length - 1)[:, None, None], axis=1)[:, 0]
    return last @ p["embed"].T, zks, zvs


def decode_compressed(p: Params, spec: CompressionSpec, cfg: ModelConfig,
                      token: jnp.ndarray, length: jnp.ndarray,
                      zks: List[jnp.ndarray], zvs: List[jnp.ndarray],
                      use_pallas: bool = True):
    """One compressed decode step — the serving hot path.

    token [B]; length [B] (cached tokens; the new token sits at `length`).
    zks[l] [B,S,g,rk], zvs[l] [B,S,rv] are read-only caches assembled by the
    rust kvcache; the new entries are *returned* (rust appends them).
    Calls the L1 pallas kernels when use_pallas (the AOT decode graph does).
    """
    b = token.shape[0]
    s_len = zks[0].shape[1]
    x = p["embed"][token]
    cos_t, sin_t = rope_tables(cfg, cfg.max_seq)
    cos_p, sin_p = cos_t[length], sin_t[length]
    cos_c, sin_c = cos_t[:s_len], sin_t[:s_len]
    valid = jnp.arange(s_len)[None] <= length[:, None]
    new_zks: List[jnp.ndarray] = []
    new_zvs: List[jnp.ndarray] = []
    for l in range(cfg.n_layers):
        g = spec.n_groups(cfg)
        rk = spec.key_ranks[l]
        xn = rmsnorm(x, p[f"L{l}.ln1"])
        q = (xn @ p[f"L{l}.wq"]).reshape(b, cfg.n_heads, cfg.d_head)
        q = ref.rope_rotate(q, cos_p[:, None, :], sin_p[:, None, :])
        zk_new = (xn @ p[f"L{l}.Lk"]).reshape(b, g, rk)
        zv_new = xn @ p[f"L{l}.Lv"]
        new_zks.append(zk_new.reshape(b, g * rk))
        new_zvs.append(zv_new)
        zk = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n[None], (i, 0, 0)))(
            zks[l], zk_new, length)
        zv = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n[None], (i, 0)))(
            zvs[l], zv_new, length)
        if use_pallas:
            scores = grouped_key_scores(q, zk, p[f"L{l}.Rk"], cos_c, sin_c)
        else:
            scores = ref.ref_grouped_key_scores(q, zk, p[f"L{l}.Rk"], cos_c, sin_c)
        scores = jnp.where(valid[:, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = latent_ctx(probs, zv) if use_pallas else ref.ref_latent_ctx(probs, zv)
        x = x + ctx.reshape(b, cfg.n_heads * spec.value_ranks[l]) @ p[f"L{l}.wo_fused"]
        x = x + _swiglu(p, l, rmsnorm(x, p[f"L{l}.ln2"]))
    x = rmsnorm(x, p["norm_f"])
    return x @ p["embed"].T, new_zks, new_zvs


MODELS: Dict[str, ModelConfig] = {
    "tiny-mha": ModelConfig(name="tiny-mha", n_heads=8, n_kv_heads=8),
    "tiny-gqa": ModelConfig(name="tiny-gqa", n_heads=8, n_kv_heads=4),
}
