"""Synthetic corpus + evaluation task generators.

The paper evaluates on WikiText-2 / PTB / C4 (perplexity), six zero-shot QA
suites (choice log-likelihood accuracy) and LongBench (long-context tasks).
None of those are available offline, so this module builds a *structured
synthetic language* with learnable regularities:

  - fixed world knowledge (animal→sound, thing→color, name→city maps),
  - in-context facts ("bob has a red key ."),
  - copy / repetition / alternation patterns,
  - counting sequences,
  - key-value and needle statements for long-context recall.

A ~4M-param byte-level transformer trained on this corpus learns the
regularities well enough that KV-cache compression quality differences are
measurable — which is the quantity every paper table reports (relative
degradation vs. compression ratio), not absolute perplexity.

Three held-out perplexity splits with distinct sentence-type mixtures stand in
for Wiki2/PTB/C4; six multiple-choice generators stand in for
OBQA/Hella/PIQA/ARC-e/ARC-c/Wino; eight long-context generation tasks stand in
for the LongBench subset. Everything is deterministic given a seed; the same
seeds are recorded in artifacts/manifest.json so the rust eval harness
regenerates byte-identical task instances (see rust/src/eval/tasks.rs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

# ---------------------------------------------------------------------------
# Deterministic RNG shared with rust (rust/src/util/rng.rs implements the same
# xorshift64* generator so task instances match across languages).
# ---------------------------------------------------------------------------


class Rng:
    """xorshift64* — tiny, fast, identical in python and rust."""

    def __init__(self, seed: int):
        self.state = (seed | 1) & 0xFFFFFFFFFFFFFFFF

    def next_u64(self) -> int:
        x = self.state
        x ^= (x >> 12) & 0xFFFFFFFFFFFFFFFF
        x = (x ^ (x << 25)) & 0xFFFFFFFFFFFFFFFF
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & 0xFFFFFFFFFFFFFFFF

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def choice(self, seq: Sequence):
        return seq[self.below(len(seq))]

    def shuffle(self, xs: list) -> list:
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]
        return xs


# ---------------------------------------------------------------------------
# Vocabulary of the synthetic language (short ASCII words; byte tokenizer).
# ---------------------------------------------------------------------------

NAMES = ["bob", "ana", "tim", "eva", "sam", "lia", "max", "zoe", "ned", "ivy"]
COLORS = ["red", "blue", "green", "gold", "gray", "pink"]
OBJECTS = ["key", "cup", "hat", "map", "pen", "box", "bag", "jar"]
FOODS = ["tea", "pie", "jam", "rice", "corn", "soup"]
ANIMAL_SOUND = {
    "dog": "barks", "cat": "purrs", "cow": "moos", "owl": "hoots",
    "bee": "buzzes", "pig": "oinks", "hen": "clucks", "fox": "yips",
}
THING_COLOR = {
    "sky": "blue", "grass": "green", "sun": "gold", "snow": "white",
    "coal": "black", "rose": "red", "sea": "blue", "ash": "gray",
}
NAME_CITY = {
    "bob": "rome", "ana": "oslo", "tim": "lima", "eva": "cairo",
    "sam": "kyoto", "lia": "paris", "max": "quito", "zoe": "delhi",
}
DIGITS = ["zero", "one", "two", "three", "four", "five", "six", "seven", "eight", "nine"]
COUNT_CYCLE = DIGITS[1:]  # one..nine
PATTERN_WORDS = ["da", "po", "ki", "lu", "mo", "ta", "re", "su"]
FILLER = [
    "the day was calm and long", "rain fell on the old roof",
    "a small wind moved the leaves", "people walked along the road",
    "the market opened at dawn", "boats came back to the shore",
    "clouds drifted over the hills", "lamps glowed in the street",
]

VOCAB_SIZE = 256  # byte-level


def encode(text: str) -> List[int]:
    return list(text.encode("utf-8"))


def decode(toks: Sequence[int]) -> str:
    return bytes(int(t) & 0xFF for t in toks).decode("utf-8", errors="replace")


# ---------------------------------------------------------------------------
# Sentence generators. Each returns a plain string ending in " ."
# ---------------------------------------------------------------------------


def s_fact(r: Rng) -> str:
    return f"{r.choice(NAMES)} has a {r.choice(COLORS)} {r.choice(OBJECTS)} ."


def s_likes(r: Rng) -> str:
    return f"{r.choice(NAMES)} likes {r.choice(COLORS)} {r.choice(FOODS)} ."


def s_agreement(r: Rng) -> str:
    a = r.choice(list(ANIMAL_SOUND))
    return f"the {a} {ANIMAL_SOUND[a]} ."


def s_world(r: Rng) -> str:
    t = r.choice(list(THING_COLOR))
    return f"q color of {t} ? a {THING_COLOR[t]} ."


def s_city(r: Rng) -> str:
    n = r.choice(list(NAME_CITY))
    return f"{n} lives in {NAME_CITY[n]} ."


def s_count(r: Rng) -> str:
    i = r.below(len(COUNT_CYCLE) - 3)
    return "count " + " ".join(COUNT_CYCLE[i:i + 4]) + " ."


def s_pattern(r: Rng) -> str:
    a, b = r.choice(PATTERN_WORDS), r.choice(PATTERN_WORDS)
    while b == a:
        b = r.choice(PATTERN_WORDS)
    return f"pattern {a} {b} {a} {b} {a} {b} ."


def s_copy(r: Rng) -> str:
    ws = [r.choice(PATTERN_WORDS + COLORS) for _ in range(3)]
    seg = " ".join(ws)
    return f"say {seg} ; say {seg} ."


def s_code(r: Rng) -> str:
    n = r.choice(NAMES)
    ds = " ".join(r.choice(DIGITS) for _ in range(3))
    return f"code {n} is {ds} . {n} code again {ds} ."


def s_kv(r: Rng) -> str:
    k = r.choice(OBJECTS)
    v = r.choice(COLORS)
    return f"item {k} maps to {v} . item {k} maps to {v} ."


def s_magic(r: Rng) -> str:
    w = r.choice(PATTERN_WORDS) + r.choice(["na", "to", "mi", "ra"])
    return f"the magic word is {w} . remember the magic word {w} ."


def s_filler(r: Rng) -> str:
    return r.choice(FILLER) + " ."


# Style mixtures: three distinct distributions standing in for Wiki2/PTB/C4.
STYLES: Dict[str, List] = {
    "wiki": [s_fact, s_likes, s_city, s_world, s_filler, s_agreement],
    "ptb": [s_count, s_pattern, s_copy, s_agreement, s_filler],
    "c4": [s_fact, s_code, s_kv, s_magic, s_pattern, s_likes, s_world, s_filler],
}
TRAIN_MIX = [
    s_fact, s_likes, s_agreement, s_world, s_city, s_count, s_pattern,
    s_copy, s_code, s_kv, s_magic, s_filler,
]


def gen_text(r: Rng, n_tokens: int, sentences: List) -> List[int]:
    """Concatenate sentences until at least n_tokens bytes, then truncate."""
    toks: List[int] = []
    while len(toks) < n_tokens:
        toks.extend(encode(r.choice(sentences)(r) + " "))
    return toks[:n_tokens]


def train_stream(seed: int, n_tokens: int) -> List[int]:
    return gen_text(Rng(seed), n_tokens, TRAIN_MIX)


def ppl_split(name: str, seed: int, n_tokens: int) -> List[int]:
    return gen_text(Rng(seed + {"wiki": 11, "ptb": 23, "c4": 37}[name]), n_tokens, STYLES[name])


# ---------------------------------------------------------------------------
# Zero-shot multiple-choice tasks (paper: OBQA, Hella, PIQA, ARC-e/c, Wino).
# Each instance: (context string, choices list, answer index). Scored by
# summed token log-likelihood of each choice continuation, lm-eval style.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MCInstance:
    context: str
    choices: List[str]
    answer: int


def mc_cloze(r: Rng) -> MCInstance:
    """Grammar cloze: object after 'has a <color>' must be an OBJECT."""
    n, c, o = r.choice(NAMES), r.choice(COLORS), r.choice(OBJECTS)
    ctx = f"{n} has a {c} "
    wrong = [r.choice(FOODS), r.choice(list(ANIMAL_SOUND)), r.choice(DIGITS)]
    choices = [o] + wrong[:3]
    idx = list(range(len(choices)))
    r.shuffle(idx)
    return MCInstance(ctx, [choices[i] for i in idx], idx.index(0))


def mc_recall(r: Rng) -> MCInstance:
    """In-context fact recall: restate a color fact stated two sentences ago."""
    n, c, o = r.choice(NAMES), r.choice(COLORS), r.choice(OBJECTS)
    mid = s_filler(r)
    ctx = f"{n} has a {c} {o} . {mid} {n} has a "
    wrong = [x for x in COLORS if x != c]
    choices = [c, wrong[r.below(len(wrong))], wrong[(r.below(len(wrong) - 1) + 1) % len(wrong)]]
    idx = list(range(3))
    r.shuffle(idx)
    return MCInstance(ctx, [choices[i] for i in idx], idx.index(0))


def mc_agreement(r: Rng) -> MCInstance:
    a = r.choice(list(ANIMAL_SOUND))
    ctx = f"the {a} "
    wrong = [v for k, v in ANIMAL_SOUND.items() if k != a]
    choices = [ANIMAL_SOUND[a], wrong[r.below(len(wrong))], wrong[(r.below(len(wrong) - 1) + 1) % len(wrong)]]
    idx = list(range(3))
    r.shuffle(idx)
    return MCInstance(ctx, [choices[i] for i in idx], idx.index(0))


def mc_world(r: Rng) -> MCInstance:
    t = r.choice(list(THING_COLOR))
    ctx = f"q color of {t} ? a "
    truth = THING_COLOR[t]
    # sorted() pins the order: set iteration depends on hash randomization,
    # which would break both determinism and python↔rust parity.
    wrong = [c for c in sorted(set(THING_COLOR.values())) if c != truth]
    choices = [truth, wrong[r.below(len(wrong))], wrong[(r.below(len(wrong) - 1) + 1) % len(wrong)]]
    idx = list(range(3))
    r.shuffle(idx)
    return MCInstance(ctx, [choices[i] for i in idx], idx.index(0))


def mc_order(r: Rng) -> MCInstance:
    i = r.below(len(COUNT_CYCLE) - 3)
    ctx = "count " + " ".join(COUNT_CYCLE[i:i + 3]) + " "
    truth = COUNT_CYCLE[i + 3]
    wrong = [w for w in COUNT_CYCLE if w != truth]
    choices = [truth, wrong[r.below(len(wrong))], wrong[(r.below(len(wrong) - 1) + 1) % len(wrong)]]
    idx = list(range(3))
    r.shuffle(idx)
    return MCInstance(ctx, [choices[i] for i in idx], idx.index(0))


def mc_parity(r: Rng) -> MCInstance:
    a, b = r.choice(PATTERN_WORDS), r.choice(PATTERN_WORDS)
    while b == a:
        b = r.choice(PATTERN_WORDS)
    ctx = f"pattern {a} {b} {a} {b} {a} "
    wrong = [w for w in PATTERN_WORDS if w != b]
    choices = [b, wrong[r.below(len(wrong))], wrong[(r.below(len(wrong) - 1) + 1) % len(wrong)]]
    idx = list(range(3))
    r.shuffle(idx)
    return MCInstance(ctx, [choices[i] for i in idx], idx.index(0))


MC_TASKS = {
    "cloze": mc_cloze,       # ~OBQA
    "recall": mc_recall,     # ~Hella
    "agree": mc_agreement,   # ~PIQA
    "world": mc_world,       # ~ARC-e
    "order": mc_order,       # ~ARC-c
    "parity": mc_parity,     # ~Wino
}


def gen_mc(task: str, seed: int, n: int) -> List[MCInstance]:
    r = Rng(seed * 7919 + sum(map(ord, task)))
    return [MC_TASKS[task](r) for _ in range(n)]


# ---------------------------------------------------------------------------
# Long-context generation tasks (paper: LongBench 8-task subset). Each
# instance: (prompt string, expected continuation string). Metric: prefix
# exact-match rate of the greedy continuation, decoded through the serving
# engine (rust) or the jax reference (python tests).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LongInstance:
    prompt: str
    expected: str


def _filler_tokens(r: Rng, n_chars: int) -> str:
    parts = []
    total = 0
    while total < n_chars:
        s = r.choice(TRAIN_MIX[:8])(r) + " "
        parts.append(s)
        total += len(s)
    return "".join(parts)


def lt_needle(r: Rng, ctx_chars: int) -> LongInstance:
    w = r.choice(PATTERN_WORDS) + r.choice(["na", "to", "mi", "ra"])
    pre = _filler_tokens(r, ctx_chars // 2)
    post = _filler_tokens(r, ctx_chars // 2 - 40)
    return LongInstance(
        f"{pre}the magic word is {w} . remember the magic word {w} . {post}the magic word is ", w)


def lt_kvrecall(r: Rng, ctx_chars: int) -> LongInstance:
    pairs = [(r.choice(OBJECTS), r.choice(COLORS)) for _ in range(6)]
    body = " ".join(f"item {k} maps to {v} . item {k} maps to {v} ." for k, v in pairs)
    fill = _filler_tokens(r, max(0, ctx_chars - len(body) - 40))
    k, v = pairs[r.below(len(pairs))]
    return LongInstance(f"{body} {fill}item {k} maps to ", v)


def lt_code(r: Rng, ctx_chars: int) -> LongInstance:
    n = r.choice(NAMES)
    ds = " ".join(r.choice(DIGITS) for _ in range(3))
    pre = _filler_tokens(r, ctx_chars // 3)
    post = _filler_tokens(r, ctx_chars // 3)
    return LongInstance(f"{pre}code {n} is {ds} . {n} code again {ds} . {post}code {n} is ", ds)


def lt_copy(r: Rng, ctx_chars: int) -> LongInstance:
    ws = [r.choice(PATTERN_WORDS + COLORS) for _ in range(3)]
    seg = " ".join(ws)
    fill = _filler_tokens(r, max(0, ctx_chars - len(seg) * 2 - 20))
    return LongInstance(f"{fill}say {seg} ; say ", seg)


def lt_lastname(r: Rng, ctx_chars: int) -> LongInstance:
    fill = _filler_tokens(r, ctx_chars - 60)
    n = r.choice(list(NAME_CITY))
    return LongInstance(f"{fill}{n} lives in ", NAME_CITY[n])


def lt_pattern(r: Rng, ctx_chars: int) -> LongInstance:
    a, b = r.choice(PATTERN_WORDS), r.choice(PATTERN_WORDS)
    while b == a:
        b = r.choice(PATTERN_WORDS)
    fill = _filler_tokens(r, ctx_chars - 50)
    return LongInstance(f"{fill}pattern {a} {b} {a} {b} {a} ", b)


def lt_world(r: Rng, ctx_chars: int) -> LongInstance:
    fill = _filler_tokens(r, ctx_chars - 40)
    t = r.choice(list(THING_COLOR))
    return LongInstance(f"{fill}q color of {t} ? a ", THING_COLOR[t])


def lt_agree(r: Rng, ctx_chars: int) -> LongInstance:
    fill = _filler_tokens(r, ctx_chars - 30)
    a = r.choice(list(ANIMAL_SOUND))
    return LongInstance(f"{fill}the {a} ", ANIMAL_SOUND[a])


LONG_TASKS = {
    "needle": lt_needle,     # ~Qasper (find buried info)
    "kvrecall": lt_kvrecall, # ~TREC (classification by stated mapping)
    "code": lt_code,         # ~TriviaQA
    "copy": lt_copy,         # ~LCC (code/segment completion)
    "lastname": lt_lastname, # ~SAMSum
    "pattern": lt_pattern,   # ~RepoBench-P
    "world": lt_world,       # ~QMSum
    "agree": lt_agree,       # ~MultiNews
}


def gen_long(task: str, seed: int, n: int, ctx_chars: int) -> List[LongInstance]:
    r = Rng(seed * 104729 + sum(map(ord, task)))
    return [LONG_TASKS[task](r, ctx_chars) for _ in range(n)]


def calibration_batch(seed: int, n_seqs: int, seq_len: int) -> List[List[int]]:
    """Calibration sequences (paper: 256 samples of WikiText-2)."""
    r = Rng(seed + 777)
    return [gen_text(r, seq_len, TRAIN_MIX) for _ in range(n_seqs)]
