"""Head-wise Similarity-aware Reordering (paper §3.2 "Head Reordering").

Greedy grouping over the CKA similarity matrix: repeatedly take the
highest-similarity pair, open a group for it (until the group budget g is
exhausted) or extend an existing group with capacity; leftover heads join the
group whose members they are most similar to. The returned permutation lists
groups consecutively, so grouped SVD can slice contiguous head blocks.
"""

from __future__ import annotations

from typing import List

import numpy as np


def greedy_group_heads(sim: np.ndarray, group_size: int) -> List[int]:
    """Return a permutation of range(h): reordered position p holds original
    head perm[p]; heads of group j occupy positions j*s..(j+1)*s-1."""
    h = sim.shape[0]
    assert h % group_size == 0, "heads must divide evenly into groups"
    n_groups = h // group_size
    # all unordered pairs sorted by similarity, descending; ties broken by
    # index for determinism across python/rust.
    pairs = [(i, j) for i in range(h) for j in range(i + 1, h)]
    pairs.sort(key=lambda p: (-sim[p[0], p[1]], p[0], p[1]))
    groups: List[List[int]] = []
    assigned = [-1] * h  # head -> group index

    for i, j in pairs:
        ai, aj = assigned[i], assigned[j]
        if ai == -1 and aj == -1:
            if len(groups) < n_groups:
                groups.append([i, j])
                assigned[i] = assigned[j] = len(groups) - 1
        elif ai == -1 and aj != -1:
            if len(groups[aj]) < group_size:
                groups[aj].append(i)
                assigned[i] = aj
        elif aj == -1 and ai != -1:
            if len(groups[ai]) < group_size:
                groups[ai].append(j)
                assigned[j] = ai

    # Any stragglers (possible when n_groups filled before everyone paired):
    for head in range(h):
        if assigned[head] != -1:
            continue
        best, best_sim = -1, -np.inf
        for gi, members in enumerate(groups):
            if len(members) >= group_size:
                continue
            avg = float(np.mean([sim[head, m] for m in members]))
            if avg > best_sim:
                best, best_sim = gi, avg
        if best == -1:  # no open group yet (e.g. h == group_size)
            groups.append([head])
            assigned[head] = len(groups) - 1
        else:
            groups[best].append(head)
            assigned[head] = best

    perm = [m for g in groups for m in g]
    assert sorted(perm) == list(range(h))
    return perm


def within_group_similarity(sim: np.ndarray, perm: List[int], group_size: int) -> float:
    """Mean pairwise CKA inside groups — the quantity Fig. 2 visualizes
    (higher after reordering)."""
    h = len(perm)
    total, count = 0.0, 0
    for g0 in range(0, h, group_size):
        members = perm[g0:g0 + group_size]
        for a in range(len(members)):
            for b in range(a + 1, len(members)):
                total += sim[members[a], members[b]]
                count += 1
    return total / max(count, 1)
