"""Fisher information + compression-ratio allocation (paper Alg. 1 l.4-5).

The empirical Fisher information of a projection matrix is the sum of squared
loss gradients over the calibration set, F(W) = Σ_batch ||∂L/∂W||² — the
importance proxy both Palu and ReCalKV use to allocate per-layer ranks, and
the quantity behind the paper's §1 observation that Fisher(W_v) ≫ Fisher(W_k)
(reproduced by `repro tables --figure fisher`).

Allocation: the target ratio ρ fixes a per-token float budget
B = (1-ρ) · Σ_l 2·kv_dim. Layer/matrix weights are F^τ (τ=0.5 damping);
each matrix gets budget B·w/Σw, clamped to [r_min, full] and rounded to a
multiple of 4, then a redistribution pass nudges ranks until the achieved
ratio is within half a rounding step of the target.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..model import ModelConfig, Params, loss_full

R_MIN = 4
R_STEP = 4


def fisher_info(params: Params, cfg: ModelConfig,
                batches: List[np.ndarray]) -> Dict[str, float]:
    """Empirical Fisher of every K/V projection: {"L{l}.wk": F, "L{l}.wv": F}."""
    grad_fn = jax.jit(jax.grad(lambda p, t: loss_full(p, cfg, t)))
    acc: Dict[str, float] = {}
    for toks in batches:
        g = grad_fn(params, jnp.asarray(toks, jnp.int32))
        for l in range(cfg.n_layers):
            for mat in ("wk", "wv"):
                key = f"L{l}.{mat}"
                val = float(jnp.sum(jnp.square(g[key])))
                acc[key] = acc.get(key, 0.0) + val
    return acc


def _round_clamp(r: float, full: int) -> int:
    ri = int(round(r / R_STEP)) * R_STEP
    return max(R_MIN, min(full, ri))


def allocate_ranks(fisher: Dict[str, float], cfg: ModelConfig, ratio: float,
                   group_size: int, tau: float = 0.5
                   ) -> Tuple[List[int], List[int]]:
    """Distribute the (1-ρ) budget across layers/matrices by damped Fisher.

    Returns (key_ranks per layer — rank PER GROUP — and value_ranks per
    layer). Per-token cache cost of layer l is g·rk_l + rv_l; the full cost
    is 2·kv_dim per layer.
    """
    n = cfg.kv_dim
    g = cfg.n_kv_heads // group_size
    # Keys and Values each keep a (1-ρ) share of their own axis; Fisher
    # weights distribute it across *layers* (paper Alg. 1 l.5 allocates
    # per-layer ratios). A joint K/V pool would starve Keys completely —
    # Fisher(W_v) ≫ Fisher(W_k) (paper §1 analysis, reproduced in
    # `repro tables --figure fisher`) — and break attention structure.
    budget_k = (1.0 - ratio) * cfg.n_layers * n
    budget_v = (1.0 - ratio) * cfg.n_layers * n
    budget = budget_k + budget_v
    w_k = np.array([fisher[f"L{l}.wk"] ** tau for l in range(cfg.n_layers)])
    w_v = np.array([fisher[f"L{l}.wv"] ** tau for l in range(cfg.n_layers)])
    key_ranks = [_round_clamp(budget_k * w_k[l] / w_k.sum() / g, group_size * cfg.d_head)
                 for l in range(cfg.n_layers)]
    value_ranks = [_round_clamp(budget_v * w_v[l] / w_v.sum(), n)
                   for l in range(cfg.n_layers)]

    def cost() -> float:
        return sum(g * key_ranks[l] + value_ranks[l] for l in range(cfg.n_layers))

    # Redistribution: nudge the matrix with the best (worst) Fisher-per-float
    # until the achieved budget matches the target within one step.
    guard = 0
    while cost() > budget + R_STEP * g / 2 and guard < 1000:
        # shrink the least-important shrinkable matrix
        cands = [(w_k[l], "k", l) for l in range(cfg.n_layers) if key_ranks[l] > R_MIN]
        cands += [(w_v[l], "v", l) for l in range(cfg.n_layers) if value_ranks[l] > R_MIN]
        if not cands:
            break
        _, kind, l = min(cands)
        if kind == "k":
            key_ranks[l] -= R_STEP
        else:
            value_ranks[l] -= R_STEP
        guard += 1
    while cost() < budget - R_STEP * g / 2 and guard < 2000:
        cands = [(w_k[l], "k", l) for l in range(cfg.n_layers)
                 if key_ranks[l] + R_STEP <= group_size * cfg.d_head]
        cands += [(w_v[l], "v", l) for l in range(cfg.n_layers)
                  if value_ranks[l] + R_STEP <= n]
        if not cands:
            break
        _, kind, l = max(cands)
        if kind == "k":
            key_ranks[l] += R_STEP
        else:
            value_ranks[l] += R_STEP
        guard += 1
    return key_ranks, value_ranks


def achieved_ratio(key_ranks: List[int], value_ranks: List[int],
                   cfg: ModelConfig, group_size: int) -> float:
    """Fraction of per-token KV cache floats removed (the paper's RATIO)."""
    g = cfg.n_kv_heads // group_size
    kept = sum(g * rk + rv for rk, rv in zip(key_ranks, value_ranks))
    return 1.0 - kept / (cfg.n_layers * 2 * cfg.kv_dim)
