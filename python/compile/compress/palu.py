"""Palu G-LRD baseline (Chang et al., 2024) — the paper's comparator.

Differences from ReCalKV, per the two papers:

  | axis                | Palu G-LRD            | ReCalKV                      |
  |---------------------|-----------------------|------------------------------|
  | key decomposition   | grouped SVD, identity | grouped SVD over CKA-reordered
  |                     | head order            | heads (HSR)                  |
  | whitening           | none                  | SVD-LLM whitening (keys)     |
  | value decomposition | grouped SVD (size 4)  | full-matrix SVD              |
  | value calibration   | none                  | offline alternating LS (OCMF)|
  | rank allocation     | Fisher-guided         | Fisher-guided (same)         |
  | output fusion       | R_v folded into W_o   | R_v folded into W_o (same)   |

Both methods share every substrate in this repo (allocation, fusion, runtime
layout), so measured gaps isolate the paper's two contributions. The grouped
value factors are laid out as one flat latent of dim r_v with a block-sparse
fused W̃_o, so Palu variants run through the identical decode graph — no
runtime advantage or penalty for either method (see DESIGN.md §6).
"""

# The implementation lives in pipeline.py (build_variant with method="palu");
# this module documents the mapping and pins the constants.

GROUP_SIZE_MHA = 4  # kv-heads per group for the 8-kv-head MHA model
GROUP_SIZE_GQA = 2  # for the 4-kv-head GQA model (2 groups, like the paper's 4-of-32)
