"""Offline Calibration (paper §3.3, Eq. 6-8).

Alternating closed-form refinement of the value factors L_v, R_v under the
calibration-data metric E = Σ_x ||x(W - L R)||² = tr((W-LR)ᵀ M (W-LR)) with
M = XᵀX:

  R-step (Eq. 8, data-aware normal equations):
      R ← (Lᵀ M L + εI)⁻¹ Lᵀ M W
  L-step (Eq. 7; the M-dependence cancels when M ≻ 0):
      L ← W Rᵀ (R Rᵀ + εI)⁻¹

Each step is the exact minimizer of E in its argument, so E is monotonically
non-increasing — asserted in python/tests/test_calibrate.py and mirrored by
rust/tests/compress_tests.rs. Iteration stops after `max_iters` or when the
relative improvement drops below `tol`.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .svd import recon_error


def _ridge_solve(a: np.ndarray, b: np.ndarray, eps_scale: float = 1e-8) -> np.ndarray:
    """Solve (A + εI) X = B with a trace-scaled ridge for stability."""
    d = a.shape[0]
    eps = eps_scale * float(np.trace(a)) / d + 1e-12
    return np.linalg.solve(a + eps * np.eye(d, dtype=a.dtype), b)


def calibrate(w: np.ndarray, l: np.ndarray, r: np.ndarray, m: np.ndarray,
              max_iters: int = 8, tol: float = 1e-6
              ) -> Tuple[np.ndarray, np.ndarray, list]:
    """Refine (L, R) to locally minimize the calibration error (Eq. 6).

    Returns (L', R', history) where history[i] is E after iteration i
    (history[0] is the pre-calibration error).
    """
    err = recon_error(w, l, r, m)
    history = [err]
    for _ in range(max_iters):
        # R-step (Eq. 8): (Lᵀ M L) R = Lᵀ M W
        lm = l.T @ m
        r = _ridge_solve(lm @ l, lm @ w)
        # L-step (Eq. 7): L (R Rᵀ) = W Rᵀ  ⇒ solve on the transposed system
        rrt = r @ r.T
        l = _ridge_solve(rrt, r @ w.T).T
        new_err = recon_error(w, l, r, m)
        history.append(new_err)
        if err - new_err <= tol * max(err, 1e-30):
            break
        err = new_err
    return l, r, history
