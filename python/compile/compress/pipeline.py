"""End-to-end ReCalKV pipeline (paper Algorithm 1) + baselines/ablations.

    RECALKV(M, X, TR):
      F  ← Fisher info on calibration data            (fisher.py)
      R  ← allocate per-layer ranks from F and TR     (fisher.py)
      for each key projection W_k:
          S    ← CKA head similarity                  (cka.py)
          W_k' ← head reorder                         (reorder.py)
          L,R  ← grouped (whitened) SVD               (svd.py)
      for each value projection W_v:
          L,R  ← SVD                                  (svd.py)
          L,R  ← offline calibration                  (calibrate.py)
          W̃_o  ← matrix fusion R_v → W_o              (fuse.py)

Methods (ablation axes of paper Table 3):
  recal        HSR ✓   calibration ✓       (the paper's method)
  recal_nohsr  HSR ✗   calibration ✓
  recal_nocal  HSR ✓   calibration ✗
  recal_none   HSR ✗   calibration ✗
  palu         Palu G-LRD baseline (plain grouped SVD both K and V,
               identity order, no whitening, no calibration)

Output params use the compressed layout documented in model.py; the head
reordering is folded into W_q / W̃_o / factor layout here, at compress time
("inverse reordering" of Fig. 3), so the runtime never gathers heads.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..model import CompressionSpec, ModelConfig, Params, rmsnorm
from . import calibrate as cal
from . import cka, fisher, fuse, reorder, svd


@dataclasses.dataclass
class LayerStats:
    """Calibration statistics for one layer's attention input."""
    m: np.ndarray        # second moment XᵀX [d, d]
    x_sample: np.ndarray  # row sample of X for CKA [N, d]


@dataclasses.dataclass
class Diagnostics:
    """Per-layer diagnostics for figures, goldens and EXPERIMENTS.md."""
    cka_before: List[np.ndarray]
    cka_after: List[np.ndarray]
    kv_perms: List[List[int]]
    within_sim_before: List[float]
    within_sim_after: List[float]
    key_errors: List[float]           # data-aware recon error of W_k
    value_errors_pre: List[float]     # before calibration
    value_errors_post: List[float]    # after calibration
    calib_histories: List[List[float]]


def collect_stats(params: Params, cfg: ModelConfig,
                  batches: List[np.ndarray], sample_rows: int = 512
                  ) -> List[LayerStats]:
    """Run the full model over calibration batches, accumulating per-layer
    M = XᵀX of the attention-input activations (post-ln1) and a row sample."""

    @jax.jit
    def layer_inputs(p, tokens):
        b, s_len = tokens.shape
        x = p["embed"][tokens]
        from ..model import forward_full  # noqa: F401 (structure mirror)
        from ..kernels import ref
        from ..model import rope_tables, _swiglu
        cos, sin = rope_tables(cfg, s_len)
        causal = jnp.tril(jnp.ones((s_len, s_len), bool))
        rep = cfg.n_heads // cfg.n_kv_heads
        xs = []
        for l in range(cfg.n_layers):
            xn = rmsnorm(x, p[f"L{l}.ln1"])
            xs.append(xn.reshape(-1, cfg.d_model))
            q = (xn @ p[f"L{l}.wq"]).reshape(b, s_len, cfg.n_heads, cfg.d_head)
            k = (xn @ p[f"L{l}.wk"]).reshape(b, s_len, cfg.n_kv_heads, cfg.d_head)
            v = (xn @ p[f"L{l}.wv"]).reshape(b, s_len, cfg.n_kv_heads, cfg.d_head)
            q = ref.rope_rotate(q, cos[None, :, None, :], sin[None, :, None, :])
            k = ref.rope_rotate(k, cos[None, :, None, :], sin[None, :, None, :])
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
            sc = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(jnp.float32(cfg.d_head))
            sc = jnp.where(causal[None, None], sc, -1e30)
            pr = jax.nn.softmax(sc, axis=-1)
            ctx = jnp.einsum("bhts,bshd->bthd", pr, v).reshape(b, s_len, cfg.q_dim)
            x = x + ctx @ p[f"L{l}.wo"]
            x = x + _swiglu(p, l, rmsnorm(x, p[f"L{l}.ln2"]))
        return xs

    ms = [np.zeros((cfg.d_model, cfg.d_model), np.float64) for _ in range(cfg.n_layers)]
    samples: List[List[np.ndarray]] = [[] for _ in range(cfg.n_layers)]
    rows_kept = [0] * cfg.n_layers
    for toks in batches:
        xs = layer_inputs(params, jnp.asarray(toks, jnp.int32))
        for l, xl in enumerate(xs):
            xl = np.asarray(xl, np.float64)
            ms[l] += xl.T @ xl
            if rows_kept[l] < sample_rows:
                take = min(sample_rows - rows_kept[l], xl.shape[0])
                samples[l].append(xl[:take].astype(np.float32))
                rows_kept[l] += take
    return [LayerStats(m=ms[l].astype(np.float32),
                       x_sample=np.concatenate(samples[l], axis=0))
            for l in range(cfg.n_layers)]


def default_group_size(cfg: ModelConfig) -> int:
    """Group size scaling the paper's 4-of-32 to our head counts (2 groups)."""
    return max(1, cfg.n_kv_heads // 2)


def build_variant(params: Params, cfg: ModelConfig, method: str, ratio: float,
                  stats: List[LayerStats], fisher_scores: Dict[str, float],
                  group_size: int | None = None
                  ) -> Tuple[Params, CompressionSpec, Diagnostics]:
    """Compress `params` with `method` at target `ratio` (Algorithm 1)."""
    assert method in ("recal", "recal_nohsr", "recal_nocal", "recal_none", "palu")
    use_hsr = method in ("recal", "recal_nocal")
    use_cal = method in ("recal", "recal_nohsr")
    is_palu = method == "palu"
    use_whiten = not is_palu
    gs = group_size or default_group_size(cfg)
    g = cfg.n_kv_heads // gs

    key_ranks, value_ranks = fisher.allocate_ranks(fisher_scores, cfg, ratio, gs)
    if is_palu:
        # grouped value factors need rv divisible by the number of V groups
        value_ranks = [max(g * 4, rv - rv % g) for rv in value_ranks]

    new_params: Dict[str, np.ndarray] = {
        k: np.asarray(v) for k, v in params.items()
        if not any(k.endswith(suf) for suf in (".wk", ".wv", ".wo"))
    }
    diag = Diagnostics([], [], [], [], [], [], [], [], [])
    perms: List[Tuple[int, ...]] = []

    for l in range(cfg.n_layers):
        w_k = np.asarray(params[f"L{l}.wk"], np.float32)
        w_v = np.asarray(params[f"L{l}.wv"], np.float32)
        w_o = np.asarray(params[f"L{l}.wo"], np.float32)
        w_q = np.asarray(params[f"L{l}.wq"], np.float32)
        m = stats[l].m

        # ----- Keys: HSR + grouped SVD (paper §3.2) -----
        sim = cka.head_similarity_matrix(stats[l].x_sample, w_k, cfg.n_kv_heads)
        perm = (reorder.greedy_group_heads(sim, gs) if use_hsr
                else list(range(cfg.n_kv_heads)))
        diag.cka_before.append(sim)
        diag.cka_after.append(sim[np.ix_(perm, perm)])
        diag.kv_perms.append(perm)
        diag.within_sim_before.append(
            reorder.within_group_similarity(sim, list(range(cfg.n_kv_heads)), gs))
        diag.within_sim_after.append(reorder.within_group_similarity(sim, perm, gs))
        l_k, r_k = svd.grouped_svd(w_k, perm, gs, key_ranks[l], cfg.d_head,
                                   m=m if use_whiten else None)
        # data-aware reconstruction error of the reordered concatenation
        w_k_perm = np.concatenate(
            [w_k[:, c * cfg.d_head:(c + 1) * cfg.d_head] for c in perm], axis=1)
        r_k_flat = _blockdiag(r_k)
        diag.key_errors.append(svd.recon_error(w_k_perm, l_k, r_k_flat, m))

        # ----- Values: SVD (+ grouped for palu) + calibration (paper §3.3) -----
        if is_palu:
            rv_g = value_ranks[l] // g
            l_v, r_v_groups = svd.grouped_svd(w_v, list(range(cfg.n_kv_heads)),
                                              gs, rv_g, cfg.d_head, m=None)
            p_heads = _grouped_value_maps(r_v_groups, cfg, gs, rv_g)
            w_v_eval = w_v
            r_v_flat = _grouped_rv_flat(r_v_groups, cfg, gs)
            diag.value_errors_pre.append(svd.recon_error(w_v_eval, l_v, r_v_flat, m))
            diag.value_errors_post.append(diag.value_errors_pre[-1])
            diag.calib_histories.append([])
        else:
            l_v, r_v = svd.svd_lowrank(w_v, value_ranks[l])
            pre = svd.recon_error(w_v, l_v, r_v, m)
            hist: List[float] = [pre]
            if use_cal:
                l_v, r_v, hist = cal.calibrate(w_v, l_v, r_v, m)
            diag.value_errors_pre.append(pre)
            diag.value_errors_post.append(hist[-1])
            diag.calib_histories.append(hist)
            rep_h = cfg.n_heads // cfg.n_kv_heads
            p_heads = [r_v[:, (i // rep_h) * cfg.d_head:(i // rep_h + 1) * cfg.d_head]
                       for i in range(cfg.n_heads)]

        # ----- Fusion + fold reordering into W_q / W̃_o (paper Fig. 3) -----
        q_order = fuse.q_head_order(perm, cfg.n_heads, cfg.n_kv_heads)
        new_params[f"L{l}.wq"] = fuse.permute_wq(w_q, q_order, cfg.d_head)
        new_params[f"L{l}.Lk"] = l_k
        new_params[f"L{l}.Rk"] = r_k
        new_params[f"L{l}.Lv"] = l_v
        new_params[f"L{l}.wo_fused"] = fuse.fuse_output_blocks(
            p_heads, w_o, q_order, cfg.d_head)
        perms.append(tuple(perm))

    spec = CompressionSpec(method=method, ratio=ratio, group_size=gs,
                           key_ranks=tuple(key_ranks),
                           value_ranks=tuple(value_ranks),
                           kv_perms=tuple(perms))
    jp = {k: jnp.asarray(v) for k, v in new_params.items()}
    return jp, spec, diag


def _blockdiag(r_k: np.ndarray) -> np.ndarray:
    """[g, rk, s·dh] group factors -> block-diagonal [g·rk, g·s·dh]."""
    g, rk, sdh = r_k.shape
    out = np.zeros((g * rk, g * sdh), r_k.dtype)
    for j in range(g):
        out[j * rk:(j + 1) * rk, j * sdh:(j + 1) * sdh] = r_k[j]
    return out


def _grouped_value_maps(r_v_groups: np.ndarray, cfg: ModelConfig,
                        group_size: int, rv_g: int) -> List[np.ndarray]:
    """Per-q-head latent→value maps for grouped value factors (Palu).

    The flat latent concatenates group latents; head i reads only its group's
    slice, so P_i is block-sparse: zeros except rows of group(kv(i))."""
    g = cfg.n_kv_heads // group_size
    rv_total = g * rv_g
    rep = cfg.n_heads // cfg.n_kv_heads
    maps: List[np.ndarray] = []
    for i in range(cfg.n_heads):
        kv = i // rep
        gj = kv // group_size
        pos = kv % group_size
        p = np.zeros((rv_total, cfg.d_head), np.float32)
        p[gj * rv_g:(gj + 1) * rv_g, :] = \
            r_v_groups[gj][:, pos * cfg.d_head:(pos + 1) * cfg.d_head]
        maps.append(p)
    return maps


def _grouped_rv_flat(r_v_groups: np.ndarray, cfg: ModelConfig,
                     group_size: int) -> np.ndarray:
    """Block-diagonal flat R_v for error accounting of grouped values."""
    g, rv_g, sdh = r_v_groups.shape
    out = np.zeros((g * rv_g, g * sdh), np.float32)
    for j in range(g):
        out[j * rv_g:(j + 1) * rv_g, j * sdh:(j + 1) * sdh] = r_v_groups[j]
    return out
