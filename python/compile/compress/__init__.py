"""Offline ReCalKV compression pipeline (paper Algorithm 1) + Palu baseline."""
