"""Centered Kernel Alignment head-similarity (paper §3.1 Eq. 2-3, §3.2 Eq. 5).

For the linear kernel, HSIC(X, Y) = ||Y_cᵀ X_c||_F² with column-centered
X_c, Y_c — algebraically identical to Tr(G̃_X G̃_Y) of Eq. 2-3 but O(n·d²)
instead of O(n²) memory, which matters for thousands of calibration tokens.
A small-n test asserts equality against the explicit Gram form.
"""

from __future__ import annotations

import numpy as np


def hsic_linear(x: np.ndarray, y: np.ndarray) -> float:
    """HSIC with linear kernels; x [n,d1], y [n,d2] (same n)."""
    xc = x - x.mean(axis=0, keepdims=True)
    yc = y - y.mean(axis=0, keepdims=True)
    c = yc.T @ xc
    return float(np.sum(c * c))


def hsic_gram(x: np.ndarray, y: np.ndarray) -> float:
    """Explicit Gram-matrix HSIC (Eq. 2-3) — O(n²), used only in tests."""
    n = x.shape[0]
    h = np.eye(n) - np.ones((n, n)) / n
    gx = h @ (x @ x.T) @ h
    gy = h @ (y @ y.T) @ h
    return float(np.trace(gx @ gy))


def cka(x: np.ndarray, y: np.ndarray) -> float:
    """CKA(X, Y) ∈ [0, 1] (Eq. 3)."""
    hxy = hsic_linear(x, y)
    hxx = hsic_linear(x, x)
    hyy = hsic_linear(y, y)
    denom = np.sqrt(hxx * hyy)
    return hxy / denom if denom > 0 else 0.0


def head_similarity_matrix(x: np.ndarray, w_k: np.ndarray, n_heads: int) -> np.ndarray:
    """Pairwise CKA between key-head representations (Eq. 5).

    x [N, d] calibration activations (inputs to the key projection);
    w_k [d, n_heads*dh]. Head i's representation H_i = x @ w_k[:, i-th block].
    Returns the symmetric S ∈ [0,1]^{h×h}.
    """
    dh = w_k.shape[1] // n_heads
    heads = [x @ w_k[:, i * dh:(i + 1) * dh] for i in range(n_heads)]
    s = np.eye(n_heads)
    for i in range(n_heads):
        for j in range(i + 1, n_heads):
            v = cka(heads[i], heads[j])
            s[i, j] = s[j, i] = v
    return s
