"""Low-rank decomposition primitives: truncated SVD, data-whitened SVD
(SVD-LLM style, paper §4.1 "Implementation Details"), grouped-head SVD
(paper §3.2 "Group-head Low-rank Decomposition").

Orientation: activations are row vectors, y = x W with W ∈ R^{m×n}; the
cacheable latent is z = x L ∈ R^r and the reconstruction is y ≈ z R.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


def svd_lowrank(w: np.ndarray, r: int) -> Tuple[np.ndarray, np.ndarray]:
    """Plain truncated SVD (Eq. 1): W ≈ L R, L = U_r Σ_r^½, R = Σ_r^½ V_rᵀ."""
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    sq = np.sqrt(s[:r])
    return u[:, :r] * sq[None, :], sq[:, None] * vt[:r]


def whiten_factor(m: np.ndarray, ridge: float = 1e-4) -> Tuple[np.ndarray, np.ndarray]:
    """Cholesky whitening of the calibration second moment M = XᵀX.

    Returns (S, S_inv_t) with M + εI = S Sᵀ (S lower-triangular) so that the
    error metric ||X(W-Ŵ)||_F² equals ||Sᵀ(W-Ŵ)||_F² in expectation.
    """
    d = m.shape[0]
    eps = ridge * float(np.trace(m)) / d + 1e-12
    s = np.linalg.cholesky(m + eps * np.eye(d, dtype=m.dtype))
    s_inv_t = np.linalg.inv(s).T  # S⁻ᵀ
    return s, s_inv_t


def whitened_svd_lowrank(w: np.ndarray, r: int, m: np.ndarray,
                         ridge: float = 1e-4) -> Tuple[np.ndarray, np.ndarray]:
    """Data-aware truncated SVD minimizing ||X(W - LR)||_F² (SVD-LLM).

    SVD(Sᵀ W) = U Σ Vᵀ, keep rank r: L = S⁻ᵀ U_r Σ_r^½, R = Σ_r^½ V_rᵀ.
    """
    s, s_inv_t = whiten_factor(m, ridge)
    a = s.T @ w
    u, sv, vt = np.linalg.svd(a, full_matrices=False)
    sq = np.sqrt(sv[:r])
    return s_inv_t @ (u[:, :r] * sq[None, :]), sq[:, None] * vt[:r]


def grouped_svd(w: np.ndarray, perm: List[int], group_size: int, rank: int,
                d_head: int, m: np.ndarray | None = None,
                ridge: float = 1e-4) -> Tuple[np.ndarray, np.ndarray]:
    """Grouped-head low-rank decomposition over a (possibly reordered) head
    permutation.

    w [d, h*dh] is split head-wise; group j concatenates heads
    perm[j*s .. (j+1)*s-1] into W_gj [d, s*dh] and decomposes it at `rank`
    (whitened when M is given, plain otherwise — the Palu baseline passes
    M=None). Returns (L [d, g*rank] — group latents concatenated — and
    R [g, rank, s*dh]).  Head layout inside R follows `perm`, i.e. the
    reordered order; the inverse reordering of paper Fig. 3 is applied by the
    caller when fusing (see pipeline.py), never at runtime.
    """
    d, n = w.shape
    h = n // d_head
    assert len(perm) == h and h % group_size == 0
    g = h // group_size
    ls, rs = [], []
    for j in range(g):
        members = perm[j * group_size:(j + 1) * group_size]
        wg = np.concatenate([w[:, c * d_head:(c + 1) * d_head] for c in members], axis=1)
        if m is None:
            lg, rg = svd_lowrank(wg, rank)
        else:
            lg, rg = whitened_svd_lowrank(wg, rank, m, ridge)
        ls.append(lg)
        rs.append(rg)
    return np.concatenate(ls, axis=1), np.stack(rs, axis=0)


def recon_error(w: np.ndarray, l: np.ndarray, r: np.ndarray,
                m: np.ndarray | None = None) -> float:
    """Approximation error: ||W - LR||_F² or, with M, the data-aware
    tr((W-LR)ᵀ M (W-LR)) = E ||x(W-LR)||² (paper Eq. 6)."""
    delta = w - l @ r
    if m is None:
        return float(np.sum(delta * delta))
    return float(np.sum(delta * (m @ delta)))
