"""Matrix Fusion (paper §3.3, Eq. 9-11): fold R_v into the output projection.

With value latents z_v = x L_v shared across heads, the per-head attention
output is the rank-r_v context c_h = Σ_s p_{h,s} z_v[s]. The uncompressed
output would be Σ_h (c_h R_v^{(kv(h))}) W_o^{(h)}; fusing gives

    W̃_o[h·r_v block h] = R_v[:, kv(h)·dh : (kv(h)+1)·dh] @ W_o[h·dh block h]

so runtime computes concat_h(c_h) @ W̃_o directly — no reconstruction, no
extra matmul, which is the paper's "no additional computational overhead"
claim for the value path.

Head reordering (HSR) is folded here too: the fused W̃_o's row blocks (and
W_q's column blocks) are laid out in the *reordered* q-head order, which is
exactly the inverse-reordering of paper Fig. 3 applied at compress time.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np


def q_head_order(kv_perm: Sequence[int], n_heads: int, n_kv_heads: int) -> List[int]:
    """Expand a kv-head permutation to the q-head permutation it induces.

    q-head i belongs to kv-head i // rep (rep = h/kvh); reordered q slot
    t = p·rep + j maps to original q head kv_perm[p]·rep + j.
    """
    rep = n_heads // n_kv_heads
    return [kv_perm[p] * rep + j for p in range(n_kv_heads) for j in range(rep)]


def permute_wq(w_q: np.ndarray, q_order: Sequence[int], d_head: int) -> np.ndarray:
    """Reorder W_q's head column-blocks into the reordered q layout."""
    blocks = [w_q[:, i * d_head:(i + 1) * d_head] for i in q_order]
    return np.concatenate(blocks, axis=1)


def fuse_output_blocks(p_heads: Sequence[np.ndarray], w_o: np.ndarray,
                       q_order: Sequence[int], d_head: int) -> np.ndarray:
    """Generic fusion: p_heads[i] ∈ R^{rv×dh} maps the flat value latent to
    original q-head i's value vector (full-SVD: a column slice of R_v;
    grouped-SVD: block-sparse). Returns W̃_o [h·rv, d] with row blocks in
    reordered q order."""
    rv = p_heads[0].shape[0]
    d = w_o.shape[1]
    n_heads = len(q_order)
    out = np.empty((n_heads * rv, d), dtype=w_o.dtype)
    for t, i in enumerate(q_order):
        wo_blk = w_o[i * d_head:(i + 1) * d_head, :]
        out[t * rv:(t + 1) * rv, :] = p_heads[i] @ wo_blk
    return out


def fuse_output(r_v: np.ndarray, w_o: np.ndarray, q_order: Sequence[int],
                d_head: int, n_kv_heads: int, n_heads: int) -> np.ndarray:
    """Build W̃_o ∈ R^{h·r_v × d} with row blocks in reordered q order.

    r_v [rv, kvh·dh] — the calibrated right value factor;
    w_o [h·dh, d]    — original output projection.
    Block for reordered slot t (original q head i = q_order[t]):
        R_v[:, kv(i)·dh:(kv(i)+1)·dh] @ W_o[i·dh:(i+1)·dh, :]
    """
    rep = n_heads // n_kv_heads
    rv = r_v.shape[0]
    d = w_o.shape[1]
    out = np.empty((n_heads * rv, d), dtype=w_o.dtype)
    for t, i in enumerate(q_order):
        kv = i // rep
        rv_blk = r_v[:, kv * d_head:(kv + 1) * d_head]        # [rv, dh]
        wo_blk = w_o[i * d_head:(i + 1) * d_head, :]          # [dh, d]
        out[t * rv:(t + 1) * rv, :] = rv_blk @ wo_blk
    return out
