"""NumPy reference for the runtime latent-cache quantization (paper §4.4).

The rust cache (rust/src/quant/) quantizes stored latents per token with a
randomized *blockwise* Walsh-Hadamard transform first. Latent dims are
multiples of 4 but rarely powers of two (e.g. g·rk = 48), so the transform
runs on chunks of size 2^k where 2^k is the largest power of two dividing n
(capped at 64): outlier energy is still spread within each chunk, the
transform stays orthonormal and exactly invertible, and no padding distorts
the memory accounting. rust/tests + goldens assert bit-identical behaviour.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

MAX_BLOCK = 64


def hadamard_block_size(n: int) -> int:
    b = n & (-n)  # largest power of two dividing n
    return min(b, MAX_BLOCK)


def blockwise_hadamard(x: np.ndarray, signs: np.ndarray) -> np.ndarray:
    """y = (x·diag(signs)) (I ⊗ H_b)/√b over the last dim."""
    n = x.shape[-1]
    b = hadamard_block_size(n)
    # iterative FWHT per chunk
    y = (x * signs).reshape(-1, b).copy()
    h = 1
    while h < b:
        for start in range(0, b, 2 * h):
            a = y[:, start:start + h].copy()
            c = y[:, start + h:start + 2 * h].copy()
            y[:, start:start + h] = a + c
            y[:, start + h:start + 2 * h] = a - c
        h *= 2
    y = y / np.sqrt(np.float32(b))
    return y.reshape(*x.shape[:-1], n).astype(np.float32)


def blockwise_hadamard_inverse(y: np.ndarray, signs: np.ndarray) -> np.ndarray:
    """Inverse: (1/√b)(I⊗H_b) is symmetric orthogonal, then undo the signs."""
    x = blockwise_hadamard(y, np.ones_like(signs))
    return (x * signs).astype(np.float32)


def quant_pertoken(x: np.ndarray, bits: int) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-token quantization (round-half-away like rust's
    f32::round). Returns (q int32, scale [tokens])."""
    qmax = (1 << (bits - 1)) - 1
    amax = np.max(np.abs(x), axis=-1)
    scale = np.where(amax > 0, amax / qmax, 1.0).astype(np.float32)
    # np.round is banker's rounding; emulate rust round-half-away-from-zero
    z = x / scale[..., None]
    q = np.sign(z) * np.floor(np.abs(z) + 0.5)
    q = np.clip(q, -qmax, qmax).astype(np.int32)
    return q, scale


def dequant_pertoken(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return (q.astype(np.float32) * scale[..., None]).astype(np.float32)


def quant_roundtrip(x: np.ndarray, signs: np.ndarray, bits: int) -> np.ndarray:
    """Full cache-storage roundtrip: hadamard → quant → dequant → inverse."""
    y = blockwise_hadamard(x, signs)
    q, s = quant_pertoken(y, bits)
    return blockwise_hadamard_inverse(dequant_pertoken(q, s), signs)
