"""Build-time training of the tiny evaluation models (no optax available —
AdamW implemented from scratch).

The paper compresses *pre-trained* LLMs; offline we must produce our own
(DESIGN.md §2): a LLaMA-style byte-level LM trained on the synthetic corpus
until it has clearly learned the corpus regularities (loss ≪ log(vocab)),
so that compression-induced degradation is measurable. Weights are cached in
artifacts/<model>/weights.rtz; `make artifacts` skips training when the cache
exists.
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import ModelConfig, Params, init_params, loss_full


def adamw_init(params: Params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adamw_step(params: Params, grads: Params, state, lr: float,
               b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
               wd: float = 0.01):
    t = state["t"] + 1
    new_m, new_v, new_p = {}, {}, {}
    bc1 = 1.0 - b1 ** t.astype(jnp.float32)
    bc2 = 1.0 - b2 ** t.astype(jnp.float32)
    for k, g in grads.items():
        m = b1 * state["m"][k] + (1 - b1) * g
        v = b2 * state["v"][k] + (1 - b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        decay = 0.0 if k.endswith((".ln1", ".ln2")) or k == "norm_f" else wd
        new_p[k] = params[k] - lr * (upd + decay * params[k])
        new_m[k], new_v[k] = m, v
    return new_p, {"m": new_m, "v": new_v, "t": t}


def cosine_lr(step: int, total: int, peak: float = 3e-3, warmup: int = 40) -> float:
    if step < warmup:
        return peak * (step + 1) / warmup
    frac = (step - warmup) / max(1, total - warmup)
    return 0.1 * peak + 0.45 * peak * (1 + np.cos(np.pi * frac))


def batches(seed: int, n_steps: int, batch: int, seq: int):
    """Deterministic stream of token batches from the synthetic corpus."""
    stream = data.train_stream(seed, n_steps * batch * seq + 1)
    arr = np.asarray(stream, np.int32)
    for i in range(n_steps):
        chunk = arr[i * batch * seq:(i + 1) * batch * seq].reshape(batch, seq)
        yield chunk


def train(cfg: ModelConfig, steps: int = 600, batch: int = 16, seq: int = 256,
          seed: int = 0, log_every: int = 50) -> Tuple[Params, Dict[str, list]]:
    """Train from scratch; returns (params, history). Logged to stdout so the
    E2E run in EXPERIMENTS.md records the loss curve."""
    params = init_params(cfg, seed)
    state = adamw_init(params)
    loss_grad = jax.jit(jax.value_and_grad(lambda p, t: loss_full(p, cfg, t)))

    @jax.jit
    def opt_step(p, g, s, lr):
        return adamw_step(p, g, s, lr)

    history = {"step": [], "loss": [], "lr": []}
    t0 = time.time()
    for step, toks in enumerate(batches(seed, steps, batch, seq)):
        lr = cosine_lr(step, steps)
        loss, grads = loss_grad(params, jnp.asarray(toks))
        params, state = opt_step(params, grads, state, lr)
        if step % log_every == 0 or step == steps - 1:
            lv = float(loss)
            history["step"].append(step)
            history["loss"].append(lv)
            history["lr"].append(lr)
            print(f"[train:{cfg.name}] step {step:4d}/{steps} "
                  f"loss {lv:.4f} lr {lr:.2e} ({time.time()-t0:.0f}s)",
                  flush=True)
    return params, history
