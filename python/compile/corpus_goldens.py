"""Emit cross-language corpus/task goldens: the rust eval harness
(rust/src/eval/tasks.rs) must regenerate byte-identical instances from the
same seeds. Run after aot.py:  python -m compile.corpus_goldens --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

import numpy as np

from . import data
from .tio import save_rtz


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--ctx-chars", type=int, default=200)
    args = ap.parse_args()

    g = {}
    for split in ("wiki", "ptb", "c4"):
        g[f"split.{split}"] = np.asarray(data.ppl_split(split, args.seed, 1024), np.int32)
    for task in data.MC_TASKS:
        for i, inst in enumerate(data.gen_mc(task, args.seed, 3)):
            g[f"mc.{task}.{i}.context"] = np.frombuffer(
                inst.context.encode(), np.uint8).astype(np.int32)
            g[f"mc.{task}.{i}.choices"] = np.frombuffer(
                "|".join(inst.choices).encode(), np.uint8).astype(np.int32)
            g[f"mc.{task}.{i}.answer"] = np.asarray([inst.answer], np.int32)
    for task in data.LONG_TASKS:
        inst = data.gen_long(task, args.seed, 1, args.ctx_chars)[0]
        g[f"long.{task}.prompt"] = np.frombuffer(
            inst.prompt.encode(), np.uint8).astype(np.int32)
        g[f"long.{task}.expected"] = np.frombuffer(
            inst.expected.encode(), np.uint8).astype(np.int32)

    path = os.path.join(args.out, "corpus_goldens.rtz")
    save_rtz(path, g)
    print(f"wrote {path} ({len(g)} tensors)")


if __name__ == "__main__":
    main()
