"""Pallas kernel: latent-value attention context (OCMF decode path).

Computes ctx[b,h,:] = Σ_s probs[b,h,s] · z_v[b,s,:] — attention weights
applied directly to the *latent* value cache. Because OCMF fuses R_v into the
output projection (W̃_o = R_v W_o, precomputed offline), this rank-rv context
is the final per-head attention output; no value reconstruction ever happens
at runtime, which is the paper's "no extra computational overhead" claim for
the value path.

TPU mapping: grid (batch, seq-block); each step loads one [Sb, rv] latent
block and the matching [h, Sb] probability slab into VMEM and accumulates
`probs_blk @ z_blk` (MXU matmul) into the [h, rv] output tile, which stays
resident across the seq-block loop (revisited output block ⇒ accumulation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ctx_kernel(p_ref, zv_ref, o_ref):
    """One (batch, seq-block) tile: accumulate probs @ z_v into o_ref."""
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    p = p_ref[0]        # [h, Sb]
    z = zv_ref[0]       # [Sb, rv]
    o_ref[0] += jnp.dot(p, z, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_s",))
def latent_ctx(probs: jnp.ndarray, z_v: jnp.ndarray,
               block_s: int = 512) -> jnp.ndarray:
    """probs [B,h,S] @ z_v [B,S,rv] -> [B,h,rv] (see kernels/ref.py oracle)."""
    b, h, s_len = probs.shape
    rv = z_v.shape[-1]
    bs = min(block_s, s_len)
    assert s_len % bs == 0, f"cache len {s_len} not divisible by block {bs}"
    return pl.pallas_call(
        _ctx_kernel,
        grid=(b, s_len // bs),
        in_specs=[
            pl.BlockSpec((1, h, bs), lambda bi, si: (bi, 0, si)),
            pl.BlockSpec((1, bs, rv), lambda bi, si: (bi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, rv), lambda bi, si: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, rv), jnp.float32),
        interpret=True,
    )(probs, z_v)
