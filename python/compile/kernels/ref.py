"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Conventions (decode step):
  B   batch, S   max cache length, h  q-heads, kvh kv-heads, dh head dim,
  g   key groups, s = kvh/g kv-heads per group, rk per-group key rank,
  rv  value latent rank.

Shapes:
  q       [B, h, dh]        query for the current step, RoPE already applied
  z_k     [B, S, g, rk]     grouped key latents (cache)
  R_k     [g, rk, s*dh]     per-group right factors (reordered head layout)
  cos/sin [S, dh/2]         RoPE tables for the *cached* positions
  probs   [B, h, S]         post-softmax attention weights
  z_v     [B, S, rv]        value latents (cache)

The "inverse reordering" of paper Fig. 3 is folded offline into the factor
layout (see compress/pipeline.py), so kernels never gather heads; an explicit
gather reference is ref_scores_with_explicit_reorder, used only in tests.
"""

from __future__ import annotations

import jax.numpy as jnp


def rope_rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Apply rotary embedding. x [..., dh]; cos/sin broadcastable [..., dh/2]."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def ref_key_reconstruct(z_k: jnp.ndarray, r_k: jnp.ndarray,
                        cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct RoPE'd keys from grouped latents.

    z_k [B,S,g,rk], r_k [g,rk,s*dh] -> k [B,S,kvh,dh] (reordered head order).
    """
    b, s_len, g, rk = z_k.shape
    sdh = r_k.shape[-1]
    k = jnp.einsum("bsgr,grd->bsgd", z_k, r_k)  # [B,S,g,s*dh]
    dh = 2 * cos.shape[-1]
    sh = sdh // dh
    k = k.reshape(b, s_len, g * sh, dh)
    return rope_rotate(k, cos[None, :, None, :], sin[None, :, None, :])


def ref_grouped_key_scores(q: jnp.ndarray, z_k: jnp.ndarray, r_k: jnp.ndarray,
                           cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Scores for one decode step: q [B,h,dh] vs reconstructed keys.

    Returns [B,h,S], scaled by 1/sqrt(dh) (masking/softmax done by caller).
    q-heads map to kv-heads contiguously: kv(i) = i // (h/kvh).
    """
    k = ref_key_reconstruct(z_k, r_k, cos, sin)  # [B,S,kvh,dh]
    b, s_len, kvh, dh = k.shape
    h = q.shape[1]
    rep = h // kvh
    kq = jnp.repeat(k, rep, axis=2)  # [B,S,h,dh]
    return jnp.einsum("bhd,bshd->bhs", q, kq) / jnp.sqrt(jnp.float32(dh))


def ref_latent_ctx(probs: jnp.ndarray, z_v: jnp.ndarray) -> jnp.ndarray:
    """Latent-value context: probs [B,h,S] @ z_v [B,S,rv] -> [B,h,rv].

    This is the OCMF fused path: the per-head context stays rank-rv and is
    consumed directly by the fused output projection W̃_o = R_v W_o.
    """
    return jnp.einsum("bhs,bsr->bhr", probs, z_v)


def ref_hadamard(x: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
    """Randomized Hadamard transform over the last dim (power of two).

    y = (x * signs) H / sqrt(n) with H the Walsh-Hadamard matrix (Sylvester
    order). Orthonormal, so per-token max values shrink and int4/int3
    quantization error drops (paper §4.4 follows Palu here).
    """
    n = x.shape[-1]
    assert n & (n - 1) == 0, "hadamard dim must be a power of two"
    y = x * signs
    h = 1
    while h < n:
        y = y.reshape(*y.shape[:-1], n // (2 * h), 2, h)
        a = y[..., 0, :]
        bb = y[..., 1, :]
        y = jnp.concatenate([a + bb, a - bb], axis=-1)
        y = y.reshape(*y.shape[:-2], n)
        h *= 2
    return y / jnp.sqrt(jnp.float32(n))


def ref_hadamard_inverse(y: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ref_hadamard ((1/sqrt(n))·H is symmetric orthogonal)."""
    x = ref_hadamard(y, jnp.ones_like(signs))
    return x * signs


def ref_quant_pertoken(x: jnp.ndarray, bits: int):
    """Symmetric per-token quantization over the last dim.

    Returns (q int32 in [-qmax, qmax], scale per token). Matches
    rust/src/quant/pertoken.rs bit-for-bit given identical inputs.
    """
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale


def ref_dequant_pertoken(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ref_scores_with_explicit_reorder(q_orig: jnp.ndarray, z_k: jnp.ndarray,
                                     r_k: jnp.ndarray, cos: jnp.ndarray,
                                     sin: jnp.ndarray, kv_perm) -> jnp.ndarray:
    """Reference for the *unfolded* path of paper Fig. 3: reconstruct keys in
    reordered order, inverse-reorder back to original head order, then score
    against original-order queries. Tests assert this equals the folded path
    (kernels on reordered layout + offline-permuted W_q)."""
    k_re = ref_key_reconstruct(z_k, r_k, cos, sin)  # reordered kv-head order
    kv_perm = jnp.asarray(kv_perm)
    # reordered position p holds original head kv_perm[p]; invert the gather.
    inv = jnp.zeros_like(kv_perm).at[kv_perm].set(jnp.arange(kv_perm.shape[0]))
    k_orig = jnp.take(k_re, inv, axis=2)
    b, s_len, kvh, dh = k_orig.shape
    h = q_orig.shape[1]
    rep = h // kvh
    kq = jnp.repeat(k_orig, rep, axis=2)
    return jnp.einsum("bhd,bshd->bhs", q_orig, kq) / jnp.sqrt(jnp.float32(dh))
