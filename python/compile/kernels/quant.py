"""Pallas kernels: randomized-Hadamard transform + per-token quantization.

Paper §4.4 integrates ReCalKV with per-token KV-cache quantization, applying
a randomized Hadamard transform before quantizing to spread outliers
(following Palu). At serving time the rust cache does this on the latent
vectors it stores (rust/src/quant/); these kernels are the build-time
counterpart used (a) to validate the rust implementation bit-for-bit through
goldens and (b) to emulate quantized caches inside jax graphs for tests.

TPU mapping: the Walsh-Hadamard butterfly runs entirely in VMEM registers on
a [T_blk, n] tile (n = latent dim, power of two); quantization is a per-row
reduce + scale. Grid is (token-blocks,).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht(y: jnp.ndarray) -> jnp.ndarray:
    """In-register Walsh-Hadamard transform over the last dim (Sylvester)."""
    n = y.shape[-1]
    h = 1
    while h < n:
        y = y.reshape(*y.shape[:-1], n // (2 * h), 2, h)
        a, b = y[..., 0, :], y[..., 1, :]
        y = jnp.concatenate([a + b, a - b], axis=-1).reshape(*y.shape[:-3], n)
        h *= 2
    return y


def _had_quant_kernel(x_ref, sign_ref, q_ref, scale_ref, *, bits: int):
    x = x_ref[...] * sign_ref[...][None, :]
    y = _fwht(x) / jnp.sqrt(jnp.float32(x.shape[-1]))
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(y), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q_ref[...] = jnp.clip(jnp.round(y / scale), -qmax, qmax).astype(jnp.int32)
    scale_ref[...] = scale[:, 0]


@functools.partial(jax.jit, static_argnames=("bits", "block_t"))
def hadamard_quant(x: jnp.ndarray, signs: jnp.ndarray, bits: int = 4,
                   block_t: int = 64):
    """x [T, n] -> (q int32 [T, n], scale [T]). n must be a power of two."""
    t, n = x.shape
    bt = min(block_t, t)
    assert t % bt == 0, f"token count {t} not divisible by block {bt}"
    q, scale = pl.pallas_call(
        functools.partial(_had_quant_kernel, bits=bits),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda ti: (ti, 0)),
            pl.BlockSpec((n,), lambda ti: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, n), lambda ti: (ti, 0)),
            pl.BlockSpec((bt,), lambda ti: (ti,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, n), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        interpret=True,
    )(x, signs)
    return q, scale


def _had_dequant_kernel(q_ref, scale_ref, sign_ref, x_ref):
    y = q_ref[...].astype(jnp.float32) * scale_ref[...][:, None]
    x = _fwht(y) / jnp.sqrt(jnp.float32(y.shape[-1]))
    x_ref[...] = x * sign_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_t",))
def hadamard_dequant(q: jnp.ndarray, scale: jnp.ndarray, signs: jnp.ndarray,
                     block_t: int = 64) -> jnp.ndarray:
    """Inverse of hadamard_quant (up to quantization error)."""
    t, n = q.shape
    bt = min(block_t, t)
    assert t % bt == 0
    return pl.pallas_call(
        _had_dequant_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda ti: (ti, 0)),
            pl.BlockSpec((bt,), lambda ti: (ti,)),
            pl.BlockSpec((n,), lambda ti: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=True,
    )(q, scale, signs)
