"""Pallas kernel: grouped key reconstruction + RoPE + QKᵀ scores (HSR decode).

This is the decode hot-spot of ReCalKV's key path (paper Fig. 3): the cached
per-group latents z_g are expanded through the group's right factor R_g,
rotary embedding is applied to the reconstructed keys, and the current-step
queries are scored against them — all in one kernel so the reconstructed keys
never round-trip to HBM.

TPU mapping (paper targets CUDA; see DESIGN.md §7): the grid is
(batch, group, seq-block). For each grid step the group's factor R_g
(rk × s·dh, ≤64 KiB fp32 at our sizes) stays resident in VMEM while
seq-blocks of latents stream through the MXU (`z_blk @ R_g` is a plain
matmul); RoPE and the scaled QKᵀ contraction run on the reconstructed block
in VMEM. BlockSpecs express the HBM↔VMEM schedule the CUDA version expresses
with threadblocks.

interpret=True always: the CPU PJRT client cannot execute Mosaic
custom-calls; the interpreted kernel lowers to plain HLO inside the same
decode graph the rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scores_kernel(q_ref, zk_ref, rk_ref, cos_ref, sin_ref, o_ref, *, rep: int):
    """One (batch, group, seq-block) tile.

    q_ref   [1, hg, dh]      queries of this group's q-heads (RoPE'd)
    zk_ref  [1, Sb, 1, rk]   key latents of this group, one seq block
    rk_ref  [1, rk, s*dh]    group right factor (resident across seq blocks)
    cos/sin [Sb, dh2]        RoPE tables for the block's positions
    o_ref   [1, hg, Sb]      output scores
    """
    z = zk_ref[0, :, 0, :]                       # [Sb, rk]
    r = rk_ref[0]                                # [rk, s*dh]
    k = jnp.dot(z, r, preferred_element_type=jnp.float32)  # MXU: [Sb, s*dh]
    sb = k.shape[0]
    dh2 = cos_ref.shape[-1]
    dh = 2 * dh2
    s_heads = k.shape[-1] // dh
    k = k.reshape(sb, s_heads, dh)
    cos = cos_ref[...][:, None, :]
    sin = sin_ref[...][:, None, :]
    k1, k2 = k[..., :dh2], k[..., dh2:]
    k = jnp.concatenate([k1 * cos - k2 * sin, k1 * sin + k2 * cos], axis=-1)
    # GQA: q-heads per kv-head = rep; repeat kv-heads along the head axis.
    k = jnp.repeat(k, rep, axis=1)               # [Sb, hg, dh]
    q = q_ref[0, 0]                              # [hg, dh]
    scores = jnp.einsum("hd,shd->hs", q, k) / jnp.sqrt(jnp.float32(dh))
    o_ref[0, 0] = scores


@functools.partial(jax.jit, static_argnames=("block_s",))
def grouped_key_scores(q: jnp.ndarray, z_k: jnp.ndarray, r_k: jnp.ndarray,
                       cos: jnp.ndarray, sin: jnp.ndarray,
                       block_s: int = 512) -> jnp.ndarray:
    """Pallas entry point. Shapes as in kernels/ref.py; returns [B,h,S].

    Head layout is the *reordered* layout produced by compress/pipeline.py —
    the inverse reordering of paper Fig. 3 is folded into the factors and
    W_q/W̃_o offline, so no runtime gather is needed.
    """
    b, h, dh = q.shape
    _, s_len, g, rk = z_k.shape
    sdh = r_k.shape[-1]
    s_heads = sdh // dh
    kvh = g * s_heads
    rep = h // kvh
    hg = s_heads * rep  # q-heads per group
    bs = min(block_s, s_len)
    assert s_len % bs == 0, f"cache len {s_len} not divisible by block {bs}"
    q_g = q.reshape(b, g, hg, dh)

    out = pl.pallas_call(
        functools.partial(_scores_kernel, rep=rep),
        grid=(b, g, s_len // bs),
        in_specs=[
            pl.BlockSpec((1, 1, hg, dh), lambda bi, gi, si: (bi, gi, 0, 0)),
            pl.BlockSpec((1, bs, 1, rk), lambda bi, gi, si: (bi, si, gi, 0)),
            pl.BlockSpec((1, rk, sdh), lambda bi, gi, si: (gi, 0, 0)),
            pl.BlockSpec((bs, dh // 2), lambda bi, gi, si: (si, 0)),
            pl.BlockSpec((bs, dh // 2), lambda bi, gi, si: (si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hg, bs), lambda bi, gi, si: (bi, gi, 0, si)),
        out_shape=jax.ShapeDtypeStruct((b, g, hg, bs * (s_len // bs)), jnp.float32),
        interpret=True,
    )(q_g, z_k, r_k, cos, sin)
    return out.reshape(b, h, s_len)
