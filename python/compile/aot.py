"""AOT build: train → compress → lower → artifacts/ (runs once, build time).

Produces everything the rust runtime needs, then python exits the picture:

  artifacts/manifest.json                      models, variants, graphs, eval cfg
  artifacts/<model>/weights.rtz                trained full-model weights
  artifacts/<model>/stats.rtz                  calibration second moments
  artifacts/<model>/goldens.rtz                cross-language test vectors
  artifacts/<model>/<variant>/factors.rtz      compressed params
  artifacts/<model>/<variant>/{score,prefill,decode}.hlo.txt

Interchange is HLO *text* — jax ≥ 0.5 serialized HloModuleProto uses 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from .compress import fisher as fisher_mod
from .compress import pipeline
from .kernels import ref
from .model import (MODELS, CompressionSpec, ModelConfig, decode_compressed,
                    decode_full, forward_compressed, forward_full,
                    prefill_compressed, prefill_full)
from .tio import load_rtz, save_rtz
from .train import train

# Graph shapes (fixed at lowering; recorded in the manifest).
SCORE_BATCH, SCORE_SEQ = 4, 256
PREFILL_BATCH, PREFILL_SEQ = 4, 512
DECODE_BATCH, CACHE_LEN = 4, 512

RATIOS = (0.5, 0.6, 0.7, 0.9)
ABLATION_RATIO = 0.8
ABLATIONS = ("recal_none", "recal_nohsr", "recal_nocal", "recal")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: with weights as arguments the only sizable
    # constants left are small tables (RoPE inverse frequencies, f32[16]),
    # which must survive the text round-trip.
    return comp.as_hlo_text(True)


def param_struct(params) -> Dict[str, jax.ShapeDtypeStruct]:
    """Weights are *graph arguments* (uploaded once as resident PjRtBuffers
    by the rust runtime), not closure constants: as_hlo_text elides large
    constants by default and printing them would bloat HLO text by ~40 MB per
    graph. jax flattens dicts in sorted-key order, which matches the sorted
    .rtz archive order the rust loader uses."""
    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in params.items()}


def _write(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


def lower_full_graphs(params, cfg: ModelConfig, outdir: str,
                      shapes) -> Dict[str, str]:
    """Lower score/prefill/decode for the uncompressed baseline."""
    sb, ss, pb, ps, db, cl = shapes
    tok_s = jax.ShapeDtypeStruct((sb, ss), jnp.int32)
    tok_p = jax.ShapeDtypeStruct((pb, ps), jnp.int32)
    len_p = jax.ShapeDtypeStruct((pb,), jnp.int32)
    tok_d = jax.ShapeDtypeStruct((db,), jnp.int32)
    len_d = jax.ShapeDtypeStruct((db,), jnp.int32)
    kcache = [jax.ShapeDtypeStruct((db, cl, cfg.n_kv_heads, cfg.d_head), jnp.float32)
              for _ in range(cfg.n_layers)]
    vcache = [jax.ShapeDtypeStruct((db, cl, cfg.n_kv_heads, cfg.d_head), jnp.float32)
              for _ in range(cfg.n_layers)]

    graphs = {}
    ps = param_struct(params)

    def score(p, tokens):
        return (forward_full(p, cfg, tokens),)

    def prefill(p, tokens, length):
        logits, ks, vs = prefill_full(p, cfg, tokens, length)
        return (logits, *ks, *vs)

    def decode(p, token, length, *caches):
        ks = list(caches[:cfg.n_layers])
        vs = list(caches[cfg.n_layers:])
        logits, nk, nv = decode_full(p, cfg, token, length, ks, vs)
        return (logits, *[k.reshape(db, -1) for k in nk],
                *[v.reshape(db, -1) for v in nv])

    graphs["score"] = to_hlo_text(jax.jit(score).lower(ps, tok_s))
    graphs["prefill"] = to_hlo_text(jax.jit(prefill).lower(ps, tok_p, len_p))
    graphs["decode"] = to_hlo_text(jax.jit(decode).lower(ps, tok_d, len_d, *kcache, *vcache))
    out = {}
    for name, text in graphs.items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        _write(path, text)
        out[name] = path
    return out


def lower_compressed_graphs(params, spec: CompressionSpec, cfg: ModelConfig,
                            outdir: str, shapes) -> Dict[str, str]:
    sb, ss, pb, ps, db, cl = shapes
    g = spec.n_groups(cfg)
    tok_s = jax.ShapeDtypeStruct((sb, ss), jnp.int32)
    tok_p = jax.ShapeDtypeStruct((pb, ps), jnp.int32)
    len_p = jax.ShapeDtypeStruct((pb,), jnp.int32)
    tok_d = jax.ShapeDtypeStruct((db,), jnp.int32)
    len_d = jax.ShapeDtypeStruct((db,), jnp.int32)
    zk = [jax.ShapeDtypeStruct((db, cl, g, spec.key_ranks[l]), jnp.float32)
          for l in range(cfg.n_layers)]
    zv = [jax.ShapeDtypeStruct((db, cl, spec.value_ranks[l]), jnp.float32)
          for l in range(cfg.n_layers)]

    ps = param_struct(params)

    def score(p, tokens):
        return (forward_compressed(p, spec, cfg, tokens),)

    def prefill(p, tokens, length):
        logits, zks, zvs = prefill_compressed(p, spec, cfg, tokens, length)
        return (logits, *zks, *zvs)

    def decode(p, token, length, *caches):
        zks = list(caches[:cfg.n_layers])
        zvs = list(caches[cfg.n_layers:])
        logits, nzk, nzv = decode_compressed(p, spec, cfg, token, length,
                                             zks, zvs, use_pallas=True)
        return (logits, *nzk, *nzv)

    graphs = {
        "score": to_hlo_text(jax.jit(score).lower(ps, tok_s)),
        "prefill": to_hlo_text(jax.jit(prefill).lower(ps, tok_p, len_p)),
        "decode": to_hlo_text(jax.jit(decode).lower(ps, tok_d, len_d, *zk, *zv)),
    }
    out = {}
    for name, text in graphs.items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        _write(path, text)
        out[name] = path
    return out


def make_goldens(params, cfg: ModelConfig, stats, comp_params, spec,
                 diag) -> Dict[str, np.ndarray]:
    """Cross-language test vectors asserted by rust/tests/golden_crosscheck.rs."""
    g: Dict[str, np.ndarray] = {}
    rng = np.random.default_rng(1234)
    # score-path golden: logits for a fixed token batch (full + compressed)
    toks = rng.integers(32, 127, (2, 64)).astype(np.int32)
    g["score.tokens"] = toks
    g["score.full_logits"] = np.asarray(forward_full(params, cfg, jnp.asarray(toks)))
    g["score.comp_logits"] = np.asarray(
        forward_compressed(comp_params, spec, cfg, jnp.asarray(toks)))
    # layer-0 compression golden (rust mirror recomputes from weights+stats)
    g["w_k0"] = np.asarray(params["L0.wk"])
    g["w_v0"] = np.asarray(params["L0.wv"])
    g["w_o0"] = np.asarray(params["L0.wo"])
    g["w_q0"] = np.asarray(params["L0.wq"])
    g["m0"] = stats[0].m
    g["x_sample0"] = stats[0].x_sample
    g["cka0"] = diag.cka_before[0]
    g["perm0"] = np.asarray(diag.kv_perms[0], np.int32)
    g["Lk0"] = np.asarray(comp_params["L0.Lk"])
    g["Rk0"] = np.asarray(comp_params["L0.Rk"])
    g["Lv0"] = np.asarray(comp_params["L0.Lv"])
    g["wo_fused0"] = np.asarray(comp_params["L0.wo_fused"])
    g["key_ranks"] = np.asarray(spec.key_ranks, np.int32)
    g["value_ranks"] = np.asarray(spec.value_ranks, np.int32)
    # quant goldens (blockwise hadamard + per-token int4/int3)
    x = rng.standard_normal((16, 48)).astype(np.float32)
    signs = rng.choice([-1.0, 1.0], 48).astype(np.float32)
    g["quant.x"] = x
    g["quant.signs"] = signs
    from .quant_ref import blockwise_hadamard, quant_pertoken
    y = blockwise_hadamard(x, signs)
    g["quant.y"] = y
    for bits in (4, 3):
        q, sc = quant_pertoken(y, bits)
        g[f"quant.q{bits}"] = q.astype(np.int32)
        g[f"quant.scale{bits}"] = sc
    return g


def build_model(name: str, out: str, steps: int, train_batch: int,
                train_seq: int, quick: bool) -> Dict:
    cfg = MODELS[name]
    mdir = os.path.join(out, name)
    os.makedirs(mdir, exist_ok=True)
    wpath = os.path.join(mdir, "weights.rtz")

    if os.path.exists(wpath):
        print(f"[aot] {name}: cached weights found, skipping training")
        params = {k: jnp.asarray(v) for k, v in load_rtz(wpath).items()}
        history = {}
    else:
        params, history = train(cfg, steps=steps, batch=train_batch, seq=train_seq)
        save_rtz(wpath, {k: np.asarray(v) for k, v in params.items()})
        with open(os.path.join(mdir, "train_history.json"), "w") as f:
            json.dump(history, f)

    # calibration stats + fisher (paper: 256 wikitext-2 samples)
    n_cal = 4 if quick else 16
    cal = data.calibration_batch(seed=42, n_seqs=n_cal * 8, seq_len=256)
    cal_batches = [np.asarray(cal[i * 8:(i + 1) * 8], np.int32) for i in range(n_cal)]
    print(f"[aot] {name}: collecting calibration stats ({n_cal} batches)")
    stats = pipeline.collect_stats(params, cfg, cal_batches)
    fisher_scores = fisher_mod.fisher_info(params, cfg, cal_batches[:max(2, n_cal // 2)])
    save_rtz(os.path.join(mdir, "stats.rtz"),
             {f"m{l}": stats[l].m for l in range(cfg.n_layers)} |
             {f"x_sample{l}": stats[l].x_sample for l in range(cfg.n_layers)} |
             {"fisher_k": np.asarray([fisher_scores[f"L{l}.wk"] for l in range(cfg.n_layers)], np.float32),
              "fisher_v": np.asarray([fisher_scores[f"L{l}.wv"] for l in range(cfg.n_layers)], np.float32)})

    shapes = (SCORE_BATCH, SCORE_SEQ, PREFILL_BATCH, PREFILL_SEQ,
              DECODE_BATCH, CACHE_LEN)

    variants: Dict[str, Dict] = {}
    t0 = time.time()
    print(f"[aot] {name}: lowering full graphs")
    graphs = lower_full_graphs(params, cfg, os.path.join(mdir, "full"), shapes)
    variants["full"] = {
        "kind": "full",
        "weights": os.path.relpath(wpath, out),
        "weight_order": sorted(params.keys()),
        "graphs": {k: os.path.relpath(v, out) for k, v in graphs.items()},
    }

    jobs: List = []
    if quick:
        jobs = [("recal", 0.5), ("palu", 0.5)]
    else:
        for ratio in RATIOS:
            jobs += [("palu", ratio), ("recal", ratio)]
        if name == "tiny-mha":
            jobs += [(m, ABLATION_RATIO) for m in ABLATIONS]

    golden_saved = False
    for method, ratio in jobs:
        vname = f"{method}@{int(ratio * 100)}"
        vdir = os.path.join(mdir, vname)
        print(f"[aot] {name}/{vname}: compressing ({time.time()-t0:.0f}s)")
        comp, spec, diag = pipeline.build_variant(
            params, cfg, method, ratio, stats, fisher_scores)
        save_rtz(_ensure(vdir, "factors.rtz"),
                 {k: np.asarray(v) for k, v in comp.items()})
        print(f"[aot] {name}/{vname}: lowering graphs")
        graphs = lower_compressed_graphs(comp, spec, cfg, vdir, shapes)
        variants[vname] = {
            "kind": "compressed",
            "weights": os.path.relpath(os.path.join(vdir, "factors.rtz"), out),
            "weight_order": sorted(comp.keys()),
            "method": method, "ratio": ratio,
            "group_size": spec.group_size,
            "key_ranks": list(spec.key_ranks),
            "value_ranks": list(spec.value_ranks),
            "kv_perms": [list(p) for p in spec.kv_perms],
            "achieved_ratio": fisher_mod.achieved_ratio(
                list(spec.key_ranks), list(spec.value_ranks), cfg, spec.group_size),
            "within_sim_before": diag.within_sim_before,
            "within_sim_after": diag.within_sim_after,
            "key_errors": diag.key_errors,
            "value_errors_pre": diag.value_errors_pre,
            "value_errors_post": diag.value_errors_post,
            "graphs": {k: os.path.relpath(v, out) for k, v in graphs.items()},
        }
        if method == "recal" and not golden_saved:
            print(f"[aot] {name}: writing goldens")
            save_rtz(os.path.join(mdir, "goldens.rtz"),
                     make_goldens(params, cfg, stats, comp, spec, diag))
            # CKA matrices for Figure 2
            save_rtz(os.path.join(mdir, "cka_fig2.rtz"),
                     {f"before{l}": diag.cka_before[l] for l in range(cfg.n_layers)} |
                     {f"after{l}": diag.cka_after[l] for l in range(cfg.n_layers)} |
                     {f"perm{l}": np.asarray(diag.kv_perms[l], np.int32)
                      for l in range(cfg.n_layers)})
            golden_saved = True

    return {
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
            "d_head": cfg.d_head, "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "rope_theta": cfg.rope_theta,
        },
        "shapes": {
            "score_batch": SCORE_BATCH, "score_seq": SCORE_SEQ,
            "prefill_batch": PREFILL_BATCH, "prefill_seq": PREFILL_SEQ,
            "decode_batch": DECODE_BATCH, "cache_len": CACHE_LEN,
        },
        "variants": variants,
    }


def _ensure(d: str, fname: str) -> str:
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, fname)


def main() -> None:
    ap = argparse.ArgumentParser(description="ReCalKV AOT artifact builder")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="tiny-mha,tiny-gqa")
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--train-batch", type=int, default=8)
    ap.add_argument("--train-seq", type=int, default=256)
    ap.add_argument("--quick", action="store_true",
                    help="tiny run for CI: few steps, 2 variants")
    args = ap.parse_args()
    if args.quick:
        args.steps = min(args.steps, 30)

    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    manifest = {
        "format": 1,
        "eval": {
            "corpus_seed": 42,
            "ppl_tokens": 4096 if args.quick else 16384,
            "mc_per_task": 16 if args.quick else 100,
            "long_per_task": 4 if args.quick else 16,
            "long_ctx_chars": 200,
            "long_gen_tokens": 12,
            "quant_signs_seed": 977,
        },
        "models": {},
    }
    for name in args.models.split(","):
        manifest["models"][name] = build_model(
            name, out, args.steps, args.train_batch, args.train_seq, args.quick)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
