"""Tensor archive I/O (.rtz) — the python↔rust weight interchange format.

Layout (all little-endian):
    magic   b"RTZ1"
    u32     n_tensors
    per tensor:
        u32     name_len, then name bytes (utf-8)
        u8      dtype   (0 = f32, 1 = i32, 2 = f16)
        u8      ndim
        u32[ndim] dims
        u64     nbytes, then raw row-major bytes

The rust reader lives in rust/src/artifacts/tensors.rs and must stay in
lockstep with this writer; `golden_crosscheck.rs` asserts a round trip.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"RTZ1"
_DTYPES = {0: np.float32, 1: np.int32, 2: np.float16}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.float16): 2}


def save_rtz(path: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write a named-tensor archive. Keys are sorted for determinism."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            if arr.dtype not in _CODES:
                arr = arr.astype(np.float32)
            code = _CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def load_rtz(path: str) -> Dict[str, np.ndarray]:
    """Read a named-tensor archive written by save_rtz (or the rust writer)."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic, not an RTZ1 archive")
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nbytes)
            out[name] = np.frombuffer(raw, dtype=_DTYPES[code]).reshape(dims).copy()
    return out
