"""Compression-pipeline invariants: SVD/whitening optimality, calibration
monotonicity, CKA properties, reordering validity, fusion equivalence."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.compress import calibrate, cka, fuse, reorder, svd


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


class TestSvd:
    def test_lowrank_eckart_young_exact(self):
        rng = np.random.default_rng(0)
        w = rand(rng, 12, 3) @ rand(rng, 3, 16)
        l, r = svd.svd_lowrank(w, 3)
        np.testing.assert_allclose(l @ r, w, atol=1e-4)

    def test_whitened_optimal_under_data_metric(self):
        """Whitened SVD must beat plain SVD in the X-weighted norm when the
        calibration distribution is anisotropic (the SVD-LLM claim)."""
        rng = np.random.default_rng(1)
        d, n, r = 24, 32, 6
        w = rand(rng, d, n)
        x = rand(rng, 400, d) * 0.1
        x[:, :4] += rand(rng, 400, 4) * 3.0
        m = (x.T @ x).astype(np.float32)
        lp, rp = svd.svd_lowrank(w, r)
        lw, rw = svd.whitened_svd_lowrank(w, r, m)
        e_plain = svd.recon_error(w, lp, rp, m)
        e_white = svd.recon_error(w, lw, rw, m)
        assert e_white <= e_plain * 1.001

    @settings(max_examples=10, deadline=None)
    @given(r=st.integers(2, 8))
    def test_error_decreases_with_rank(self, r):
        rng = np.random.default_rng(r)
        w = rand(rng, 16, 20)
        l1, r1 = svd.svd_lowrank(w, r)
        l2, r2 = svd.svd_lowrank(w, r + 2)
        assert svd.recon_error(w, l2, r2) <= svd.recon_error(w, l1, r1) + 1e-5

    def test_grouped_svd_shapes_and_blockstructure(self):
        rng = np.random.default_rng(3)
        d, h, dh = 16, 8, 4
        w = rand(rng, d, h * dh)
        perm = list(range(h))
        l, r = svd.grouped_svd(w, perm, 4, 5, dh)
        assert l.shape == (d, 2 * 5)
        assert r.shape == (2, 5, 4 * dh)


class TestCalibration:
    def test_monotone_nonincreasing(self):
        rng = np.random.default_rng(4)
        w = rand(rng, 20, 24)
        x = rand(rng, 200, 20)
        m = (x.T @ x).astype(np.float32)
        l, r = svd.svd_lowrank(w, 8)
        _, _, hist = calibrate.calibrate(w, l, r, m)
        tol = 1e-6 * max(abs(hist[0]), 1.0)
        assert all(b <= a * 1.000001 + tol for a, b in zip(hist, hist[1:])), hist
        assert hist[-1] < hist[0]

    def test_improves_plain_svd_toward_whitened(self):
        """Calibration of plain-SVD factors should approach the whitened
        optimum under the same metric (paper §3.3's motivation)."""
        rng = np.random.default_rng(5)
        d, n, r = 20, 24, 5
        w = rand(rng, d, n)
        x = rand(rng, 300, d) * 0.1
        x[:, :3] += rand(rng, 300, 3) * 4.0
        m = (x.T @ x).astype(np.float32)
        l0, r0 = svd.svd_lowrank(w, r)
        lw, rw = svd.whitened_svd_lowrank(w, r, m)
        lc, rc, hist = calibrate.calibrate(w, l0, r0, m, max_iters=25)
        e_cal = hist[-1]
        e_white = svd.recon_error(w, lw, rw, m)
        e_plain = hist[0]
        assert e_cal < e_plain
        # within 25% of the data-optimal solution (ALS is a local method)
        assert e_cal <= e_white * 1.25 + 1e-6

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_never_increases_error(self, seed):
        rng = np.random.default_rng(seed)
        w = rand(rng, 10, 12)
        x = rand(rng, 50, 10)
        m = (x.T @ x).astype(np.float32)
        l, r = svd.svd_lowrank(w, 4)
        _, _, hist = calibrate.calibrate(w, l, r, m, max_iters=4)
        assert hist[-1] <= hist[0] * 1.000001


class TestCka:
    def test_linear_hsic_matches_gram_form(self):
        rng = np.random.default_rng(6)
        x, y = rand(rng, 30, 5), rand(rng, 30, 7)
        np.testing.assert_allclose(
            cka.hsic_linear(x, y), cka.hsic_gram(x, y), rtol=1e-3)

    def test_self_similarity(self):
        rng = np.random.default_rng(7)
        x = rand(rng, 40, 6)
        assert cka.cka(x, x) == pytest.approx(1.0, abs=1e-6)

    def test_orthogonal_invariance(self):
        rng = np.random.default_rng(8)
        x = rand(rng, 50, 4)
        q, _ = np.linalg.qr(rand(rng, 4, 4))
        assert cka.cka(x, x @ q) == pytest.approx(1.0, abs=1e-5)

    def test_similarity_matrix_symmetric_unit_diag(self):
        rng = np.random.default_rng(9)
        x = rand(rng, 64, 16)
        wk = rand(rng, 16, 4 * 4)
        s = cka.head_similarity_matrix(x, wk, 4)
        np.testing.assert_allclose(s, s.T, atol=1e-7)
        np.testing.assert_allclose(np.diag(s), 1.0)
        assert (s >= -1e-7).all() and (s <= 1 + 1e-7).all()


class TestReorder:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), h=st.sampled_from([4, 8]), gs=st.sampled_from([2, 4]))
    def test_valid_permutation(self, seed, h, gs):
        if h % gs:
            return
        rng = np.random.default_rng(seed)
        s = rng.uniform(0, 1, (h, h)).astype(np.float32)
        s = (s + s.T) / 2
        np.fill_diagonal(s, 1.0)
        perm = reorder.greedy_group_heads(s, gs)
        assert sorted(perm) == list(range(h))

    def test_reordering_improves_within_group_similarity(self):
        rng = np.random.default_rng(11)
        # planted structure: blocks {0,4}, {1,5}, {2,6}, {3,7} similar
        h = 8
        s = np.full((h, h), 0.1, np.float32)
        for i in range(4):
            s[i, i + 4] = s[i + 4, i] = 0.9
        np.fill_diagonal(s, 1.0)
        perm = reorder.greedy_group_heads(s, 2)
        before = reorder.within_group_similarity(s, list(range(h)), 2)
        after = reorder.within_group_similarity(s, perm, 2)
        assert after > before
        assert after == pytest.approx(0.9, abs=1e-6)


class TestFusion:
    def test_fused_output_equals_unfused(self):
        """Eq. 9-11: Attention(...)·W_o == latent-ctx·W̃_o exactly."""
        rng = np.random.default_rng(12)
        d, h, kvh, dh, rv, s = 16, 4, 4, 4, 6, 10
        w_v = rand(rng, d, kvh * dh)
        w_o = rand(rng, h * dh, d)
        l_v, r_v = svd.svd_lowrank(w_v, rv)
        x = rand(rng, s, d)
        probs = np.abs(rand(rng, h, s))
        probs /= probs.sum(-1, keepdims=True)
        # unfused: reconstruct values, attend, project
        v_full = x @ l_v @ r_v  # [s, kvh*dh]
        ctx_full = np.concatenate(
            [probs[i] @ v_full[:, i * dh:(i + 1) * dh] for i in range(h)])
        out_ref = ctx_full @ w_o
        # fused: latent ctx through W̃_o
        q_order = fuse.q_head_order(list(range(kvh)), h, kvh)
        w_tilde = fuse.fuse_output(r_v, w_o, q_order, dh, kvh, h)
        z_v = x @ l_v
        ctx_lat = np.concatenate([probs[i] @ z_v for i in range(h)])
        out_fused = ctx_lat @ w_tilde
        np.testing.assert_allclose(out_fused, out_ref, rtol=1e-4, atol=1e-4)

    def test_gqa_fusion_maps_heads_correctly(self):
        rng = np.random.default_rng(13)
        d, h, kvh, dh, rv = 16, 8, 4, 4, 6
        w_v = rand(rng, d, kvh * dh)
        w_o = rand(rng, h * dh, d)
        l_v, r_v = svd.svd_lowrank(w_v, rv)
        q_order = fuse.q_head_order(list(range(kvh)), h, kvh)
        w_tilde = fuse.fuse_output(r_v, w_o, q_order, dh, kvh, h)
        assert w_tilde.shape == (h * rv, d)
        # q-heads 0,1 share kv-head 0: their blocks use the same R_v slice
        blk0 = w_tilde[0 * rv:1 * rv]
        expect0 = r_v[:, 0:dh] @ w_o[0 * dh:1 * dh]
        np.testing.assert_allclose(blk0, expect0, atol=1e-6)

    def test_q_head_order_with_reordering(self):
        order = fuse.q_head_order([2, 0, 3, 1], 8, 4)
        assert order == [4, 5, 0, 1, 6, 7, 2, 3]
