"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles, with
hypothesis sweeps over shapes (the CORE correctness signal of the stack)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.grouped_key_attn import grouped_key_scores
from compile.kernels.latent_ctx import latent_ctx
from compile.kernels.quant import hadamard_dequant, hadamard_quant


def rope_tables(s, dh, theta=10000.0):
    pos = np.arange(s)
    inv = 1.0 / (theta ** (np.arange(0, dh, 2) / dh))
    ang = pos[:, None] * inv[None, :]
    return jnp.asarray(np.cos(ang), jnp.float32), jnp.asarray(np.sin(ang), jnp.float32)


def make_case(rng, b, s, h, kvh, dh, g, rk):
    s_heads = kvh // g
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    zk = jnp.asarray(rng.standard_normal((b, s, g, rk)), jnp.float32)
    rkm = jnp.asarray(rng.standard_normal((g, rk, s_heads * dh)), jnp.float32)
    cos, sin = rope_tables(s, dh)
    return q, zk, rkm, cos, sin


class TestGroupedKeyScores:
    @pytest.mark.parametrize("b,s,h,kvh,g,rk", [
        (1, 128, 8, 8, 2, 16),
        (2, 128, 8, 8, 4, 8),
        (3, 256, 8, 4, 2, 24),   # GQA
        (2, 128, 8, 2, 2, 12),   # GQA rep=4
    ])
    def test_matches_reference(self, b, s, h, kvh, g, rk):
        rng = np.random.default_rng(b * 100 + s + g)
        q, zk, rkm, cos, sin = make_case(rng, b, s, h, kvh, 32, g, rk)
        want = ref.ref_grouped_key_scores(q, zk, rkm, cos, sin)
        got = grouped_key_scores(q, zk, rkm, cos, sin)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        g=st.sampled_from([1, 2, 4]),
        rk=st.sampled_from([4, 12, 20]),
        blocks=st.integers(1, 3),
    )
    def test_hypothesis_shape_sweep(self, b, g, rk, blocks):
        kvh, h, dh = 4 if g <= 2 else 8, 8, 16
        if kvh % g:
            kvh = g * 2
        s = 64 * blocks
        rng = np.random.default_rng(rk + g * 10 + b)
        q, zk, rkm, cos, sin = make_case(rng, b, s, h, kvh, dh, g, rk)
        want = ref.ref_grouped_key_scores(q, zk, rkm, cos, sin)
        got = grouped_key_scores(q, zk, rkm, cos, sin, block_s=64)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_explicit_reorder_path_equivalence(self):
        """Fig. 3 equivalence: folded inverse-reordering == explicit gather."""
        rng = np.random.default_rng(7)
        b, s, h, kvh, dh, g, rk = 2, 128, 8, 8, 32, 2, 16
        perm = [3, 1, 7, 5, 0, 2, 4, 6]
        q_orig = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
        zk = jnp.asarray(rng.standard_normal((b, s, g, rk)), jnp.float32)
        rkm = jnp.asarray(rng.standard_normal((g, rk, 4 * dh)), jnp.float32)
        cos, sin = rope_tables(s, dh)
        # folded path: q permuted offline to reordered layout (MHA: q perm = kv perm)
        q_folded = q_orig[:, jnp.asarray(perm), :]
        folded = grouped_key_scores(q_folded, zk, rkm, cos, sin)
        explicit = ref.ref_scores_with_explicit_reorder(q_orig, zk, rkm, cos, sin, perm)
        # folded scores are in reordered head order; gather back
        refolded = explicit[:, jnp.asarray(perm), :]
        np.testing.assert_allclose(folded, refolded, rtol=1e-4, atol=1e-4)


class TestLatentCtx:
    @pytest.mark.parametrize("b,h,s,rv", [(1, 8, 128, 64), (2, 4, 256, 20), (3, 8, 128, 4)])
    def test_matches_reference(self, b, h, s, rv):
        rng = np.random.default_rng(b + h + rv)
        probs = jax.nn.softmax(
            jnp.asarray(rng.standard_normal((b, h, s)), jnp.float32), axis=-1)
        zv = jnp.asarray(rng.standard_normal((b, s, rv)), jnp.float32)
        want = ref.ref_latent_ctx(probs, zv)
        got = latent_ctx(probs, zv)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(b=st.integers(1, 2), rv=st.sampled_from([4, 16, 36]), blocks=st.integers(1, 4))
    def test_hypothesis_accumulation(self, b, rv, blocks):
        s = 64 * blocks
        rng = np.random.default_rng(rv * 7 + blocks)
        probs = jax.nn.softmax(
            jnp.asarray(rng.standard_normal((b, 4, s)), jnp.float32), axis=-1)
        zv = jnp.asarray(rng.standard_normal((b, s, rv)), jnp.float32)
        np.testing.assert_allclose(
            latent_ctx(probs, zv, block_s=64), ref.ref_latent_ctx(probs, zv),
            rtol=1e-4, atol=1e-5)


class TestQuantKernels:
    @pytest.mark.parametrize("bits", [4, 3])
    def test_matches_reference(self, bits):
        rng = np.random.default_rng(bits)
        x = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
        signs = jnp.asarray(rng.choice([-1.0, 1.0], 64), jnp.float32)
        q, sc = hadamard_quant(x, signs, bits=bits)
        want_y = ref.ref_hadamard(x, signs)
        want_q, want_s = ref.ref_quant_pertoken(want_y, bits)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(want_q))
        np.testing.assert_allclose(sc, want_s[:, 0], rtol=1e-6)

    def test_roundtrip_error_shrinks_with_bits(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
        signs = jnp.asarray(rng.choice([-1.0, 1.0], 64), jnp.float32)
        errs = {}
        for bits in (3, 4):
            q, sc = hadamard_quant(x, signs, bits=bits)
            back = hadamard_dequant(q, sc, signs)
            errs[bits] = float(jnp.mean(jnp.square(back - x)))
        assert errs[4] < errs[3]

    def test_hadamard_orthonormal(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
        signs = jnp.asarray(rng.choice([-1.0, 1.0], 32), jnp.float32)
        y = ref.ref_hadamard(x, signs)
        np.testing.assert_allclose(
            jnp.sum(jnp.square(y), -1), jnp.sum(jnp.square(x), -1), rtol=1e-5)
        back = ref.ref_hadamard_inverse(y, signs)
        np.testing.assert_allclose(back, x, atol=1e-5)


class TestBlockwiseQuantRef:
    """numpy reference shared with the rust cache (quant_ref.py)."""

    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([12, 20, 48, 64, 96]), bits=st.sampled_from([3, 4]))
    def test_roundtrip_bounded(self, n, bits):
        from compile.quant_ref import blockwise_hadamard, blockwise_hadamard_inverse, \
            dequant_pertoken, quant_pertoken
        rng = np.random.default_rng(n * bits)
        x = rng.standard_normal((16, n)).astype(np.float32)
        signs = rng.choice([-1.0, 1.0], n).astype(np.float32)
        y = blockwise_hadamard(x, signs)
        # orthonormal
        np.testing.assert_allclose(
            np.sum(y * y, -1), np.sum(x * x, -1), rtol=1e-4)
        q, s = quant_pertoken(y, bits)
        back = blockwise_hadamard_inverse(dequant_pertoken(q, s), signs)
        qmax = (1 << (bits - 1)) - 1
        assert np.abs(back - x).max() <= np.sqrt(n) * s.max() / 1.0
        assert np.abs(q).max() <= qmax
