"""L2 model graph consistency: full vs compressed shapes, prefill/decode
equivalence against the score path, GQA coverage."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data
from compile.compress import fisher as F, pipeline
from compile.model import (MODELS, ModelConfig, decode_compressed, decode_full,
                           forward_compressed, forward_full, init_params,
                           loss_full, prefill_compressed, prefill_full)

TINY = ModelConfig(name="test", d_model=64, n_layers=2, n_heads=4,
                   n_kv_heads=4, d_head=16, d_ff=96, max_seq=256)
TINY_GQA = ModelConfig(name="test-gqa", d_model=64, n_layers=2, n_heads=4,
                       n_kv_heads=2, d_head=16, d_ff=96, max_seq=256)


@pytest.fixture(scope="module", params=["mha", "gqa"])
def setup(request):
    cfg = TINY if request.param == "mha" else TINY_GQA
    params = init_params(cfg, seed=1)
    cal = data.calibration_batch(5, 8, 64)
    batches = [np.asarray(cal[:4], np.int32), np.asarray(cal[4:], np.int32)]
    stats = pipeline.collect_stats(params, cfg, batches, sample_rows=128)
    fs = F.fisher_info(params, cfg, batches[:1])
    comp, spec, diag = pipeline.build_variant(params, cfg, "recal", 0.5, stats, fs)
    return cfg, params, comp, spec


class TestFullModel:
    def test_forward_shapes(self, setup):
        cfg, params, _, _ = setup
        toks = jnp.zeros((2, 16), jnp.int32)
        logits = forward_full(params, cfg, toks)
        assert logits.shape == (2, 16, cfg.vocab)

    def test_loss_finite_and_near_uniform_at_init(self, setup):
        cfg, params, _, _ = setup
        toks = jnp.asarray(np.random.default_rng(0).integers(0, 255, (2, 32)), jnp.int32)
        loss = float(loss_full(params, cfg, toks))
        assert np.isfinite(loss)
        assert abs(loss - np.log(cfg.vocab)) < 1.0

    def test_causality(self, setup):
        """Changing a future token must not change past logits."""
        cfg, params, _, _ = setup
        rng = np.random.default_rng(1)
        toks = rng.integers(32, 127, (1, 24)).astype(np.int32)
        l1 = forward_full(params, cfg, jnp.asarray(toks))
        toks2 = toks.copy()
        toks2[0, 20] = 65
        l2 = forward_full(params, cfg, jnp.asarray(toks2))
        np.testing.assert_allclose(l1[0, :20], l2[0, :20], atol=1e-5)

    def test_prefill_decode_match_score(self, setup):
        cfg, params, _, _ = setup
        rng = np.random.default_rng(2)
        B, S, L = 2, 64, 17
        toks = rng.integers(32, 127, (B, S)).astype(np.int32)
        length = jnp.asarray([L, L - 5], jnp.int32)
        _, ks, vs = prefill_full(params, cfg, jnp.asarray(toks), length)
        nxt = jnp.asarray([66, 67], jnp.int32)
        logits, _, _ = decode_full(params, cfg, nxt, length, ks, vs)
        for b in range(B):
            seq = list(toks[b][: int(length[b])]) + [int(nxt[b])]
            ref = forward_full(params, cfg,
                               jnp.asarray([seq + [0] * (S - len(seq))], jnp.int32))
            np.testing.assert_allclose(
                logits[b], ref[0, len(seq) - 1], rtol=1e-3, atol=1e-3)


class TestCompressedModel:
    def test_score_shapes(self, setup):
        cfg, _, comp, spec = setup
        toks = jnp.zeros((2, 16), jnp.int32)
        logits = forward_compressed(comp, spec, cfg, toks)
        assert logits.shape == (2, 16, cfg.vocab)

    def test_compression_close_to_full_at_50pct(self, setup):
        cfg, params, comp, spec = setup
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(32, 127, (2, 32)), jnp.int32)
        lf = forward_full(params, cfg, toks)
        lc = forward_compressed(comp, spec, cfg, toks)
        # compressed logits track the full model (untrained weights, 50%)
        rel = float(jnp.abs(lf - lc).max() / (jnp.abs(lf).max() + 1e-9))
        assert rel < 0.5, rel

    def test_prefill_decode_match_score_pallas(self, setup):
        """The serving-path decode (with pallas kernels) must equal the
        teacher-forced score path — the core runtime correctness claim."""
        cfg, _, comp, spec = setup
        rng = np.random.default_rng(4)
        B, S, L = 2, 128, 21
        toks = rng.integers(32, 127, (B, S)).astype(np.int32)
        length = jnp.asarray([L, L - 7], jnp.int32)
        _, zks, zvs = prefill_compressed(comp, spec, cfg, jnp.asarray(toks), length)
        nxt = jnp.asarray([65, 66], jnp.int32)
        logits, nzk, nzv = decode_compressed(comp, spec, cfg, nxt, length, zks, zvs,
                                             use_pallas=True)
        for b in range(B):
            seq = list(toks[b][: int(length[b])]) + [int(nxt[b])]
            ref = forward_compressed(comp, spec, cfg,
                                     jnp.asarray([seq + [0] * (S - len(seq))], jnp.int32))
            np.testing.assert_allclose(
                logits[b], ref[0, len(seq) - 1], rtol=2e-3, atol=2e-3)

    def test_new_latents_match_prefill_row(self, setup):
        """Latents returned by decode equal what prefill would produce at
        that position (cache-append correctness)."""
        cfg, _, comp, spec = setup
        rng = np.random.default_rng(5)
        B, S, L = 2, 128, 30
        toks = rng.integers(32, 127, (B, S)).astype(np.int32)
        length = jnp.asarray([L, L], jnp.int32)
        _, zks, zvs = prefill_compressed(comp, spec, cfg, jnp.asarray(toks), length)
        nxt = jnp.asarray([int(toks[0, L]), int(toks[1, L])], jnp.int32)
        _, nzk, nzv = decode_compressed(comp, spec, cfg, nxt, length, zks, zvs,
                                        use_pallas=False)
        length2 = jnp.asarray([L + 1, L + 1], jnp.int32)
        _, zks2, zvs2 = prefill_compressed(comp, spec, cfg, jnp.asarray(toks), length2)
        for l in range(cfg.n_layers):
            want_k = np.asarray(zks2[l][:, L].reshape(B, -1))
            np.testing.assert_allclose(np.asarray(nzk[l]), want_k, rtol=2e-3, atol=2e-3)
            want_v = np.asarray(zvs2[l][:, L])
            np.testing.assert_allclose(np.asarray(nzv[l]), want_v, rtol=2e-3, atol=2e-3)


class TestPipelineVariants:
    @pytest.mark.parametrize("method", ["palu", "recal_nohsr", "recal_nocal", "recal_none"])
    def test_all_methods_produce_runnable_models(self, setup, method):
        cfg, params, _, _ = setup
        cal = data.calibration_batch(5, 4, 64)
        batches = [np.asarray(cal, np.int32)]
        stats = pipeline.collect_stats(params, cfg, batches, sample_rows=64)
        fs = F.fisher_info(params, cfg, batches)
        comp, spec, diag = pipeline.build_variant(params, cfg, method, 0.6, stats, fs)
        toks = jnp.zeros((1, 8), jnp.int32)
        logits = forward_compressed(comp, spec, cfg, toks)
        assert np.isfinite(np.asarray(logits)).all()
        assert spec.method == method

    def test_achieved_ratio_near_target(self, setup):
        cfg, params, _, spec = setup
        ar = F.achieved_ratio(list(spec.key_ranks), list(spec.value_ranks), cfg,
                              spec.group_size)
        assert abs(ar - 0.5) < 0.06

    def test_hsr_within_group_similarity_never_decreases(self, setup):
        cfg, params, comp, spec = setup
        cal = data.calibration_batch(5, 4, 64)
        stats = pipeline.collect_stats(params, cfg, [np.asarray(cal, np.int32)],
                                       sample_rows=64)
        fs = F.fisher_info(params, cfg, [np.asarray(cal, np.int32)])
        _, _, diag = pipeline.build_variant(params, cfg, "recal", 0.5, stats, fs)
        for b, a in zip(diag.within_sim_before, diag.within_sim_after):
            assert a >= b - 1e-9

    def test_calibration_histories_monotone(self, setup):
        cfg, params, _, _ = setup
        cal = data.calibration_batch(5, 4, 64)
        stats = pipeline.collect_stats(params, cfg, [np.asarray(cal, np.int32)],
                                       sample_rows=64)
        fs = F.fisher_info(params, cfg, [np.asarray(cal, np.int32)])
        _, _, diag = pipeline.build_variant(params, cfg, "recal", 0.5, stats, fs)
        for hist in diag.calib_histories:
            tol = 1e-6 * max(abs(hist[0]), 1.0)  # f32 noise near exact rank
            assert all(b <= a * 1.00001 + tol for a, b in zip(hist, hist[1:])), hist
