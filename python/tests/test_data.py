"""Corpus/task generator determinism + structure (the rust side mirrors
these generators byte-for-byte; see rust/tests/golden_crosscheck.rs)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data


class TestRng:
    def test_known_stream(self):
        r = data.Rng(42)
        a = [r.next_u64() for _ in range(4)]
        r2 = data.Rng(42)
        assert a == [r2.next_u64() for _ in range(4)]

    @settings(max_examples=20)
    @given(seed=st.integers(1, 2**63), n=st.integers(1, 1000))
    def test_below_in_range(self, seed, n):
        r = data.Rng(seed)
        assert all(r.below(n) < n for _ in range(20))

    def test_shuffle_is_permutation(self):
        r = data.Rng(7)
        xs = list(range(20))
        r.shuffle(xs)
        assert sorted(xs) == list(range(20))


class TestCorpus:
    def test_splits_deterministic(self):
        a = data.ppl_split("wiki", 42, 512)
        b = data.ppl_split("wiki", 42, 512)
        assert a == b

    def test_splits_distinct(self):
        assert data.ppl_split("wiki", 42, 512) != data.ppl_split("c4", 42, 512)

    def test_all_bytes_ascii(self):
        toks = data.train_stream(1, 2048)
        assert all(0 < t < 128 for t in toks)

    def test_train_stream_contains_all_patterns(self):
        text = data.decode(data.train_stream(3, 20000))
        for marker in ["has a", "likes", "count", "pattern", "say", "code",
                       "maps to", "magic word", "lives in", "q color of"]:
            assert marker in text, marker


class TestMcTasks:
    @settings(max_examples=12)
    @given(task=st.sampled_from(list(data.MC_TASKS)), seed=st.integers(1, 10_000))
    def test_instances_valid(self, task, seed):
        for inst in data.gen_mc(task, seed, 5):
            assert 0 <= inst.answer < len(inst.choices)
            assert len(set(i for i in range(len(inst.choices)))) == len(inst.choices)
            assert inst.context

    def test_answer_distribution_not_degenerate(self):
        """Shuffling must spread the gold index across positions."""
        for task in data.MC_TASKS:
            answers = [i.answer for i in data.gen_mc(task, 42, 60)]
            assert len(set(answers)) >= 2, task

    def test_correct_choice_is_semantically_right(self):
        for inst in data.gen_mc("agree", 42, 20):
            animal = inst.context.split()[1]
            assert inst.choices[inst.answer] == data.ANIMAL_SOUND[animal]
        for inst in data.gen_mc("world", 42, 20):
            thing = inst.context.split()[3]
            assert inst.choices[inst.answer] == data.THING_COLOR[thing]


class TestLongTasks:
    @settings(max_examples=10, deadline=None)
    @given(task=st.sampled_from(list(data.LONG_TASKS)), seed=st.integers(1, 1000))
    def test_instances_valid(self, task, seed):
        for inst in data.gen_long(task, seed, 2, 200):
            assert inst.expected
            assert inst.prompt.endswith(" ")
            assert len(inst.prompt) >= 100

    def test_needle_contains_needle(self):
        for inst in data.gen_long("needle", 42, 8, 200):
            assert f"the magic word is {inst.expected} ." in inst.prompt

    def test_kvrecall_answer_stated_in_context(self):
        for inst in data.gen_long("kvrecall", 42, 8, 300):
            key = inst.prompt.rsplit("item ", 1)[1].split()[0]
            assert f"item {key} maps to {inst.expected} ." in inst.prompt


class TestCalibration:
    def test_calibration_batch_shapes(self):
        cal = data.calibration_batch(42, 16, 128)
        assert len(cal) == 16
        assert all(len(s) == 128 for s in cal)
        arr = np.asarray(cal)
        assert (arr >= 0).all() and (arr < 256).all()
